//! DiCFS-hp — horizontal partitioning (paper §5.1).
//!
//! Rows are split into contiguous ranges, one per partition. Each
//! correlation batch is one Spark-shaped job:
//!
//! 1. broadcast the requested pair list,
//! 2. `mapPartitions(localCTables)` — Algorithm 2: every worker counts
//!    its rows into per-pair partial contingency tables. The counting
//!    itself runs through the [`SuEngine`] — i.e. the L1 Pallas ctable
//!    kernel when the PJRT engine is plugged in,
//! 3. `reduceByKey(sum)` — Eq. 4: element-wise merge of partial tables.
//!    The lazy scheduler fuses steps 2+3 into a single shuffle stage
//!    (`localCTables+mergeCTables`), exactly like Spark's
//!    ShuffleMapStage,
//! 4. `mapPartitions(computeSU)` + `collect` of the scalar SU values
//!    (L1 su kernel under PJRT).
//!
//! Exactness: tables carry u64 counts, merge is associative/commutative,
//! so the merged tables — and hence the SU values and the whole search —
//! are bit-identical to the sequential run on the native engine.

use std::ops::Range;
use std::sync::Arc;

use crate::cfs::{Correlator, SharedCorrelator};
use crate::core::FeatureId;
use crate::correlation::sampled::{bounds_for_pairs, default_windows, windows_len, SuBounds};
use crate::correlation::{ContingencyTable, Marginals};
use crate::data::columnar::DiscreteDataset;
use crate::dicfs::plan::{self, PlanSpec};
use crate::runtime::{ColumnPair, SuEngine};
use crate::sparklet::{Rdd, SparkletContext};

/// Distributed SU correlator over row partitions.
pub struct HorizontalCorrelator {
    data: Arc<DiscreteDataset>,
    engine: Arc<dyn SuEngine>,
    ctx: Arc<SparkletContext>,
    /// One contiguous row range per partition.
    ranges: Rdd<Range<usize>>,
    /// Exact full-column marginal counts for the sampled-bounds finish
    /// (DESIGN.md §16), shared across engine siblings.
    marginals: Arc<Marginals>,
}

impl HorizontalCorrelator {
    /// Partition `data`'s rows into `num_partitions` ranges.
    pub fn new(
        ctx: &Arc<SparkletContext>,
        data: Arc<DiscreteDataset>,
        engine: Arc<dyn SuEngine>,
        num_partitions: usize,
    ) -> Self {
        let n = data.num_rows();
        let parts = num_partitions.clamp(1, n.max(1));
        let chunk = n.div_ceil(parts);
        let ranges: Vec<Range<usize>> = (0..parts)
            .map(|p| (p * chunk).min(n)..((p + 1) * chunk).min(n))
            .collect();
        let count = ranges.len();
        Self {
            data,
            engine,
            ctx: Arc::clone(ctx),
            ranges: ctx.parallelize(ranges, count),
            marginals: Arc::new(Marginals::new()),
        }
    }

    /// A sibling correlator over the *same* row layout but a different
    /// engine. `Rdd` handles are cheap clones, so no partitioning work
    /// re-runs — this is how the engine-pool planner gets one hp lowering
    /// per engine without paying the setup twice.
    pub fn with_engine(&self, engine: Arc<dyn SuEngine>) -> Self {
        Self {
            data: Arc::clone(&self.data),
            engine,
            ctx: Arc::clone(&self.ctx),
            ranges: self.ranges.clone(),
            marginals: Arc::clone(&self.marginals),
        }
    }

    /// Resolve a pair id to borrowed columns.
    fn column_pair<'a>(data: &'a DiscreteDataset, a: FeatureId, b: FeatureId) -> ColumnPair<'a> {
        let (x, bins_x) = data.column(a);
        let (y, bins_y) = data.column(b);
        ColumnPair {
            x,
            bins_x,
            y,
            bins_y,
        }
    }

    /// Lower a pair batch to its plan IR (`pair batch → row layout →
    /// ctable shuffle → SU collect`) without running it — what the
    /// adaptive planner prices when deciding hp vs vp.
    pub fn plan(&self, pairs: &[(FeatureId, FeatureId)]) -> PlanSpec {
        plan::hp_plan(
            &self.data,
            pairs,
            &self.ctx.cluster,
            self.ranges.num_partitions(),
        )
    }

    /// Steps 1–3 of every hp job, shared by the SU batch (which appends
    /// a computeSU stage), the table job and the sampled-sketch job
    /// (which collect the merged tables directly): broadcast the pair
    /// list, count each range into per-partition partial tables through
    /// the engine, and `reduceByKey(sum)` them per pair. The `(map,
    /// reduce)` label pair only switches the stage labels, so the three
    /// job kinds stay distinguishable in metrics.
    fn merged_ctables(
        &self,
        pairs: &[(FeatureId, FeatureId)],
        ranges: Rdd<Range<usize>>,
        labels: (&'static str, &'static str),
    ) -> Rdd<(usize, ContingencyTable)> {
        // 1. Broadcast the pair list (16 bytes per pair on the wire).
        let pairs_bc = self.ctx.broadcast(pairs.to_vec(), pairs.len() * 16);

        // 2. mapPartitions(localCTables): per-range partial tables.
        let data = Arc::clone(&self.data);
        let engine = Arc::clone(&self.engine);
        let partials: Rdd<(usize, ContingencyTable)> =
            ranges.map_partitions(labels.0, move |_, ranges| {
                // The pair → column resolution does not depend on the
                // range: build the ColumnPair list once per task, not
                // once per range.
                let cps: Vec<ColumnPair> = pairs_bc
                    .iter()
                    .map(|&(a, b)| Self::column_pair(&data, a, b))
                    .collect();
                let mut out = Vec::new();
                for range in ranges {
                    let tables = engine.ctables(&cps, range.clone());
                    out.extend(tables.into_iter().enumerate());
                }
                out
            });

        // 3. reduceByKey(sum): merge partials per pair (Eq. 4).
        let reduce_parts = pairs.len().min(self.ctx.cluster.total_slots()).max(1);
        partials.reduce_by_key(
            labels.1,
            reduce_parts,
            ContingencyTable::wire_bytes,
            |a, b| a.merge(b).expect("pair tables share shape"),
        )
    }

    /// The hp **sampled-sketch job** (DESIGN.md §16): the ctable job
    /// shape, but each map task counts one deterministic sample window
    /// instead of a sub-range of the full dataset — one task per window,
    /// scanning only `Σ|window|` rows per pair. The merged tables are
    /// bit-identical to the sequential
    /// [`sampled_table`](crate::correlation::sampled::sampled_table)
    /// (u64 counts, associative merge), so hp-derived bounds equal
    /// sequential bounds and prune decisions agree across schemes.
    pub fn sampled_ctables(
        &self,
        pairs: &[(FeatureId, FeatureId)],
        windows: &[Range<usize>],
    ) -> Vec<ContingencyTable> {
        if pairs.is_empty() || windows.is_empty() {
            return vec![];
        }
        let count = windows.len();
        let ranges = self.ctx.parallelize(windows.to_vec(), count);
        let merged =
            self.merged_ctables(pairs, ranges, ("localCTablesSampled", "mergeCTablesSampled"));
        let mut collected = merged.collect_sized(|(_, t)| t.wire_bytes());
        collected.sort_by_key(|(i, _)| *i);
        debug_assert_eq!(collected.len(), pairs.len());
        collected.into_iter().map(|(_, t)| t).collect()
    }
}

/// The hp job is stateless on the driver side (it only reads the shared
/// dataset, engine and partition layout), so one correlator instance can
/// serve any number of concurrent searches — the multi-query service
/// relies on this to run one hp job per coalesced miss batch.
impl SharedCorrelator for HorizontalCorrelator {
    fn supports_ctables(&self) -> bool {
        true
    }

    /// The hp **table job** (DESIGN.md §12): steps 1–3 of the SU job over
    /// an arbitrary row range — broadcast the pair list, count the
    /// range's rows into per-partition partial tables, `reduceByKey(sum)`
    /// — then collect the *merged tables* (their full wire size) instead
    /// of running the computeSU stage. Partition count follows the
    /// correlator's row layout, clamped to the range length (a delta of
    /// 50 rows does not launch 240 tasks).
    fn compute_ctables(
        &self,
        pairs: &[(FeatureId, FeatureId)],
        rows: Range<usize>,
    ) -> Vec<ContingencyTable> {
        if pairs.is_empty() {
            return vec![];
        }
        debug_assert!(rows.end <= self.data.num_rows());
        let len = rows.len();
        let parts = self.ranges.num_partitions().clamp(1, len.max(1));
        let chunk = len.div_ceil(parts).max(1);
        let ranges: Vec<Range<usize>> = (0..parts)
            .map(|p| {
                (rows.start + p * chunk).min(rows.end)..(rows.start + (p + 1) * chunk).min(rows.end)
            })
            .collect();
        let count = ranges.len();
        let ranges = self.ctx.parallelize(ranges, count);

        let merged = self.merged_ctables(pairs, ranges, ("localCTablesDelta", "mergeCTablesDelta"));
        let mut collected = merged.collect_sized(|(_, t)| t.wire_bytes());
        collected.sort_by_key(|(i, _)| *i);
        debug_assert_eq!(collected.len(), pairs.len());
        collected.into_iter().map(|(_, t)| t).collect()
    }

    fn compute_batch(&self, pairs: &[(FeatureId, FeatureId)]) -> Vec<f64> {
        if pairs.is_empty() {
            return vec![];
        }
        // Steps 1–3 (pair broadcast, localCTables, mergeCTables) are the
        // shared job prefix.
        let merged =
            self.merged_ctables(pairs, self.ranges.clone(), ("localCTables", "mergeCTables"));

        // 4. SU finish *in parallel on the CTables RDD* (paper §5.1: "this
        // calculation can therefore be performed in parallel by processing
        // the local rows of this RDD"), then collect only the scalars.
        let engine = Arc::clone(&self.engine);
        let sus = merged.map_partitions("computeSU", move |_, tables| {
            // Borrow the merged tables in place — no clone per table.
            let refs: Vec<&ContingencyTable> = tables.iter().map(|(_, t)| t).collect();
            let values = engine.su_from_tables(&refs);
            tables
                .iter()
                .map(|(i, _)| *i)
                .zip(values)
                .collect::<Vec<(usize, f64)>>()
        });
        // Shared job-assembly tail (plan.rs): collect 8 B scalars,
        // restore request order.
        plan::collect_su(&sus, pairs.len())
    }

    /// Sound SU intervals from the hp sampled-sketch job (DESIGN.md §16):
    /// run [`Self::sampled_ctables`] over the deterministic default
    /// windows, then finish into intervals on the driver with exact
    /// full-column marginals. Declines only when the dataset is too small
    /// to carry sample windows.
    fn compute_bounds_batch(&self, pairs: &[(FeatureId, FeatureId)]) -> Option<SuBounds> {
        if pairs.is_empty() {
            return Some(SuBounds::default());
        }
        let windows = default_windows(self.data.num_rows());
        if windows.is_empty() {
            return None;
        }
        let tables = self.sampled_ctables(pairs, &windows);
        Some(bounds_for_pairs(
            &self.data,
            &self.marginals,
            pairs,
            &tables,
            windows_len(&windows),
        ))
    }
}

impl Correlator for HorizontalCorrelator {
    fn compute(&mut self, pairs: &[(FeatureId, FeatureId)]) -> Vec<f64> {
        self.compute_batch(pairs)
    }

    fn compute_bounds(&mut self, pairs: &[(FeatureId, FeatureId)]) -> Option<SuBounds> {
        self.compute_bounds_batch(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::CLASS_ID;
    use crate::correlation::su::symmetrical_uncertainty;
    use crate::data::synth::{kddcup99_like, SynthConfig};
    use crate::discretize::discretize_dataset;
    use crate::runtime::NativeEngine;
    use crate::sparklet::ClusterConfig;

    fn setup(parts: usize) -> (Arc<SparkletContext>, HorizontalCorrelator, Arc<DiscreteDataset>) {
        let ds = kddcup99_like(&SynthConfig {
            rows: 900,
            seed: 33,
            features: Some(10),
        });
        let dd = Arc::new(discretize_dataset(&ds).unwrap());
        let ctx = SparkletContext::new(ClusterConfig::with_nodes(3));
        let corr =
            HorizontalCorrelator::new(&ctx, Arc::clone(&dd), Arc::new(NativeEngine), parts);
        (ctx, corr, dd)
    }

    #[test]
    fn matches_direct_su_exactly() {
        let (_ctx, mut corr, dd) = setup(7);
        let pairs = vec![(0, CLASS_ID), (1, CLASS_ID), (0, 1), (2, 5)];
        let got = corr.compute(&pairs);
        for (i, &(a, b)) in pairs.iter().enumerate() {
            let (x, bx) = dd.column(a);
            let (y, by) = dd.column(b);
            let want = symmetrical_uncertainty(x, bx, y, by);
            assert_eq!(got[i], want, "pair {:?}", (a, b));
        }
    }

    #[test]
    fn partition_count_does_not_change_results() {
        let pairs = vec![(0, CLASS_ID), (3, 4), (7, CLASS_ID)];
        let (_c1, mut one, _) = setup(1);
        let (_c2, mut many, _) = setup(64);
        assert_eq!(one.compute(&pairs), many.compute(&pairs));
    }

    #[test]
    fn records_spark_shaped_stages() {
        use crate::sparklet::StageKind;

        let (ctx, mut corr, _) = setup(5);
        let _ = corr.compute(&[(0, 1), (2, CLASS_ID)]);
        let m = ctx.metrics();
        // The scheduler fuses localCTables into the mergeCTables shuffle
        // stage; computeSU runs as its own map stage at collect time.
        let fused = m
            .stages
            .iter()
            .find(|s| s.label == "localCTables+mergeCTables")
            .expect("fused shuffle stage");
        assert_eq!(fused.kind, StageKind::Shuffle);
        assert_eq!(fused.fused_ops, 2);
        let labels: Vec<&str> = m.stages.iter().map(|s| s.label.as_str()).collect();
        assert!(labels.contains(&"computeSU"));
        assert!(labels.contains(&"collect"));
        assert_eq!(m.broadcast_bytes.len(), 1); // the pair list
        assert!(m.total_shuffle_bytes() > 0);
    }

    #[test]
    fn empty_batch() {
        let (_ctx, mut corr, _) = setup(3);
        assert!(corr.compute(&[]).is_empty());
    }

    #[test]
    fn plan_predicts_the_job_it_lowers_to() {
        // The IR is honest: the bytes the plan promises are the bytes
        // the executed job records.
        let (ctx, corr, _) = setup(6);
        let pairs = vec![(0, CLASS_ID), (1, 2), (3, CLASS_ID)];
        let spec = corr.plan(&pairs);
        let _ = corr.compute_batch(&pairs);
        let m = ctx.metrics();
        let shuffle = m
            .stages
            .iter()
            .find(|s| s.label == "localCTables+mergeCTables")
            .expect("shuffle stage");
        let sh = spec.shuffle.expect("hp plans a shuffle");
        assert_eq!(sh.bytes, shuffle.shuffle_bytes);
        assert_eq!(spec.broadcast_bytes, m.broadcast_bytes[0]);
        let collect = m.stages.iter().find(|s| s.label == "collect").unwrap();
        assert_eq!(spec.collect_bytes, collect.collect_bytes);
        assert_eq!(spec.num_pairs, pairs.len());
    }

    #[test]
    fn correlator_is_shareable_across_threads() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<HorizontalCorrelator>();

        // Concurrent batches through one &self correlator agree with the
        // direct computation — the property the service scheduler uses.
        let (_ctx, corr, dd) = setup(4);
        let (corr, dd) = (&corr, &dd);
        std::thread::scope(|s| {
            for offset in 0..3usize {
                s.spawn(move || {
                    let pairs = vec![(offset, CLASS_ID), (offset, offset + 1)];
                    let got = corr.compute_batch(&pairs);
                    for (i, &(a, b)) in pairs.iter().enumerate() {
                        let (x, bx) = dd.column(a);
                        let (y, by) = dd.column(b);
                        assert_eq!(got[i], symmetrical_uncertainty(x, bx, y, by));
                    }
                });
            }
        });
    }

    #[test]
    fn ctable_job_matches_direct_tables_and_supports_deltas() {
        let (_ctx, corr, dd) = setup(7);
        assert!(corr.supports_ctables());
        let pairs = vec![(0, CLASS_ID), (1, 4), (2, CLASS_ID)];
        let n = dd.num_rows();

        // Full-range tables equal the driver-side computation exactly.
        let full = corr.compute_ctables(&pairs, 0..n);
        for (t, &(a, b)) in full.iter().zip(&pairs) {
            let (x, bx) = dd.column(a);
            let (y, by) = dd.column(b);
            assert_eq!(t, &ContingencyTable::from_columns(x, bx, y, by));
        }

        // Base ⊕ delta == full, bit-identically — the append invariant.
        let split = n - 137;
        let base = corr.compute_ctables(&pairs, 0..split);
        let delta = corr.compute_ctables(&pairs, split..n);
        for ((mut b, d), f) in base.into_iter().zip(delta).zip(&full) {
            b.merge(&d).unwrap();
            assert_eq!(&b, f);
        }
    }

    #[test]
    fn sampled_job_matches_sequential_sketch_bitwise() {
        use crate::correlation::sampled::sampled_table;

        let (ctx, corr, dd) = setup(6);
        let pairs = vec![(0, CLASS_ID), (1, 4), (2, CLASS_ID), (3, 7)];
        let windows = default_windows(dd.num_rows());
        assert!(!windows.is_empty());

        // One map task per sample window, distinct stage labels.
        let tables = corr.sampled_ctables(&pairs, &windows);
        let m = ctx.metrics();
        let fused = m
            .stages
            .iter()
            .find(|s| s.label == "localCTablesSampled+mergeCTablesSampled")
            .expect("fused sampled shuffle stage");
        assert_eq!(fused.task_secs.len(), windows.len());

        // The merged distributed tables equal the driver-side sampled
        // tables bit-for-bit — so do the bounds derived from them.
        for (t, &(a, b)) in tables.iter().zip(&pairs) {
            let (x, bx) = dd.column(a);
            let (y, by) = dd.column(b);
            assert_eq!(t, &sampled_table(x, bx, y, by, &windows));
        }
    }

    #[test]
    fn bounds_contain_exact_su_and_match_sequential() {
        use crate::cfs::sequential::SequentialCorrelator;

        let (_ctx, corr, dd) = setup(5);
        let pairs = vec![(0, CLASS_ID), (2, 6), (5, CLASS_ID)];
        let hp = corr.compute_bounds_batch(&pairs).expect("900 rows sketch");
        assert_eq!(hp.intervals.len(), pairs.len());
        assert!(hp.sampled_cells > 0);

        let mut seq = SequentialCorrelator::new(&dd);
        let sq = seq.compute_bounds(&pairs).unwrap();
        for (i, &(a, b)) in pairs.iter().enumerate() {
            let (x, bx) = dd.column(a);
            let (y, by) = dd.column(b);
            let exact = symmetrical_uncertainty(x, bx, y, by);
            let iv = hp.intervals[i];
            assert!(
                iv.lo <= exact && exact <= iv.hi,
                "pair {:?}: {exact} ∉ [{}, {}]",
                (a, b),
                iv.lo,
                iv.hi
            );
            // Scheme-independence: hp intervals == sequential intervals,
            // bit-for-bit — the property the prune protocol rests on.
            assert_eq!(iv, sq.intervals[i]);
        }

        // Empty batch succeeds without launching a job.
        let empty = corr.compute_bounds_batch(&[]).unwrap();
        assert!(empty.intervals.is_empty());
    }

    #[test]
    fn more_partitions_than_rows_clamped() {
        let (_ctx, mut corr, dd) = setup(10_000);
        let got = corr.compute(&[(0, CLASS_ID)]);
        let (x, bx) = dd.column(0);
        let (y, by) = dd.column(CLASS_ID);
        assert_eq!(got[0], symmetrical_uncertainty(x, bx, y, by));
    }
}
