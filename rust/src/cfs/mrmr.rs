//! mRMR — minimum-Redundancy Maximum-Relevance feature selection.
//!
//! The greedy info-theoretic selector of Peng et al., as distributed in
//! the Spark framework of arXiv 1610.04154: each round picks the
//! candidate maximizing `MI(f; class) − mean_{s ∈ S} MI(f; s)` over the
//! already-selected set `S`. Every term is a pairwise mutual information
//! — exactly the scalars the measure-keyed substrate (DESIGN.md §17)
//! finishes from the *same* contingency tables CFS builds for SU, so a
//! warm CFS cache answers mRMR's redundancy terms without recounting
//! anything.
//!
//! The search is written against the [`Correlator`] trait like
//! best-first CFS is; the correlator must return **MI** values (in the
//! service this is a [`Measure::Mi`](crate::correlation::Measure)
//! miss-forwarder, sequentially it is [`SequentialMiCorrelator`]).
//! Rounds batch one `(candidate, last-picked)` pair per remaining
//! candidate, so the scheduler coalesces each round into one job the
//! same way it coalesces best-first expansion waves.

use crate::cfs::Correlator;
use crate::core::{FeatureId, SelectionResult, CLASS_ID};
use crate::correlation::{mi_from_table, ContingencyTable};
use crate::data::columnar::DiscreteDataset;

/// mRMR search configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MrmrConfig {
    /// How many features to select (clamped to the feature count).
    pub num_select: usize,
}

impl Default for MrmrConfig {
    fn default() -> Self {
        Self { num_select: 8 }
    }
}

/// The greedy mRMR search over any MI [`Correlator`].
#[derive(Debug, Default)]
pub struct MrmrSearch {
    /// Search configuration.
    pub config: MrmrConfig,
}

impl MrmrSearch {
    /// Search with the given configuration.
    pub fn new(config: MrmrConfig) -> Self {
        Self { config }
    }

    /// Run the greedy selection over `num_features` candidates.
    ///
    /// Deterministic: candidates are scanned in ascending id order with
    /// strict `>` comparison, so score ties always resolve to the lowest
    /// id — the property the scheme/engine equivalence battery pins.
    pub fn run(&self, num_features: usize, correlator: &mut dyn Correlator) -> SelectionResult {
        let k = self.config.num_select.min(num_features);
        if k == 0 {
            return SelectionResult {
                selected: Vec::new(),
                merit: 0.0,
                iterations: 0,
                correlations_computed: 0,
                pruned_candidates: 0,
                sampled_cells: 0,
                locally_predictive_added: Vec::new(),
            };
        }

        // Round 0: relevance MI(f; class) for every feature, one batch.
        let rel_pairs: Vec<(FeatureId, FeatureId)> =
            (0..num_features).map(|f| (f, CLASS_ID)).collect();
        let relevance = correlator.compute(&rel_pairs);
        let mut computed = num_features;

        let mut selected: Vec<FeatureId> = Vec::with_capacity(k);
        let mut in_set = vec![false; num_features];
        // Σ_{s ∈ S} MI(f; s), maintained incrementally per candidate.
        let mut red_sum = vec![0.0f64; num_features];
        let mut objective = 0.0f64;

        for round in 0..k {
            if round > 0 {
                // One batched wave: each remaining candidate against the
                // feature picked last round (all other redundancy terms
                // are already in `red_sum`).
                let last = *selected.last().expect("round > 0");
                let wave: Vec<(FeatureId, FeatureId)> = (0..num_features)
                    .filter(|&f| !in_set[f])
                    .map(|f| (f, last))
                    .collect();
                let vals = correlator.compute(&wave);
                computed += wave.len();
                for (&(f, _), &v) in wave.iter().zip(&vals) {
                    red_sum[f] += v;
                }
            }
            let mut best: Option<(FeatureId, f64)> = None;
            for f in 0..num_features {
                if in_set[f] {
                    continue;
                }
                let score = if round == 0 {
                    relevance[f]
                } else {
                    relevance[f] - red_sum[f] / round as f64
                };
                if best.map_or(true, |(_, s)| score > s) {
                    best = Some((f, score));
                }
            }
            let (pick, score) = best.expect("k <= num_features leaves a candidate");
            in_set[pick] = true;
            selected.push(pick);
            objective = score;
        }

        selected.sort_unstable();
        SelectionResult {
            selected,
            // The mRMR objective of the last accepted candidate — the
            // greedy analogue of CFS's subset merit.
            merit: objective,
            iterations: k,
            correlations_computed: computed,
            pruned_candidates: 0,
            sampled_cells: 0,
            locally_predictive_added: Vec::new(),
        }
    }
}

/// Computes MI directly from a local [`DiscreteDataset`] — the mRMR
/// analogue of [`SequentialCorrelator`](crate::cfs::SequentialCorrelator)
/// and the reference oracle the distributed variants are asserted
/// against.
pub struct SequentialMiCorrelator<'a> {
    data: &'a DiscreteDataset,
}

impl<'a> SequentialMiCorrelator<'a> {
    /// MI correlator over the given discretized dataset.
    pub fn new(data: &'a DiscreteDataset) -> Self {
        Self { data }
    }
}

impl Correlator for SequentialMiCorrelator<'_> {
    fn compute(&mut self, pairs: &[(FeatureId, FeatureId)]) -> Vec<f64> {
        pairs
            .iter()
            .map(|&(a, b)| {
                let (xa, aa) = self.data.column(a);
                let (xb, ab) = self.data.column(b);
                mi_from_table(&ContingencyTable::from_columns(xa, aa, xb, ab))
            })
            .collect()
    }
}

/// Sequential mRMR: discretize, then greedy-select with the local MI
/// correlator. The reference oracle for every distributed mRMR path.
#[derive(Debug, Default)]
pub struct SequentialMrmr {
    /// Search configuration.
    pub config: MrmrConfig,
}

impl SequentialMrmr {
    /// mRMR with the given search configuration.
    pub fn new(config: MrmrConfig) -> Self {
        Self { config }
    }

    /// Full pipeline: discretize then select.
    pub fn select(&self, ds: &crate::data::columnar::Dataset) -> SelectionResult {
        let dd = crate::discretize::discretize_dataset(ds).expect("discretization failed");
        self.select_discrete(&dd)
    }

    /// Selection over an already-discretized dataset.
    pub fn select_discrete(&self, dd: &DiscreteDataset) -> SelectionResult {
        let mut correlator = SequentialMiCorrelator::new(dd);
        MrmrSearch::new(self.config).run(dd.num_features(), &mut correlator)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{higgs_like, with_roles, FeatureRole, SynthConfig};

    #[test]
    fn selects_requested_count_and_is_deterministic() {
        let ds = higgs_like(&SynthConfig {
            rows: 1_200,
            seed: 31,
            features: Some(12),
        });
        let m = SequentialMrmr::new(MrmrConfig { num_select: 5 });
        let a = m.select(&ds);
        let b = m.select(&ds);
        assert_eq!(a, b);
        assert_eq!(a.selected.len(), 5);
        assert!(a.selected.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(a.iterations, 5);
        // Round 0 computes all relevances; round r computes the
        // remaining candidates.
        assert_eq!(a.correlations_computed, 12 + 11 + 10 + 9 + 8);
    }

    #[test]
    fn first_pick_is_max_relevance_and_avoids_noise() {
        let s = with_roles(
            "higgs",
            &SynthConfig {
                rows: 2_000,
                seed: 37,
                features: Some(16),
            },
        );
        let dd = crate::discretize::discretize_dataset(&s.dataset).unwrap();
        let mut mi = SequentialMiCorrelator::new(&dd);
        let rel_pairs: Vec<_> = (0..dd.num_features()).map(|f| (f, CLASS_ID)).collect();
        let rel = mi.compute(&rel_pairs);
        let argmax = (0..rel.len()).fold(0, |b, f| if rel[f] > rel[b] { f } else { b });

        let r = SequentialMrmr::new(MrmrConfig { num_select: 4 }).select(&s.dataset);
        assert!(r.selected.contains(&argmax), "max-relevance feature kept");
        for &f in &r.selected {
            assert_ne!(s.roles[f], FeatureRole::Noise, "selected noise feature {f}");
        }
    }

    #[test]
    fn redundant_copy_is_deferred() {
        // In the epsilon family redundant features are near-copies of
        // relevant ones: mRMR's redundancy penalty must prefer a fresh
        // relevant feature over a copy of the first pick.
        let s = with_roles(
            "epsilon",
            &SynthConfig {
                rows: 1_500,
                seed: 41,
                features: Some(20),
            },
        );
        let r = SequentialMrmr::new(MrmrConfig { num_select: 6 }).select(&s.dataset);
        let relevant = r
            .selected
            .iter()
            .filter(|&&f| s.roles[f] == FeatureRole::Relevant)
            .count();
        assert!(
            relevant > r.selected.len() / 2,
            "mostly originals expected, got {relevant}/{}",
            r.selected.len()
        );
    }

    #[test]
    fn zero_select_is_empty() {
        let ds = higgs_like(&SynthConfig {
            rows: 400,
            seed: 43,
            features: Some(6),
        });
        let r = SequentialMrmr::new(MrmrConfig { num_select: 0 }).select(&ds);
        assert!(r.selected.is_empty());
        assert_eq!(r.correlations_computed, 0);
    }
}
