//! Discretization — the preprocessing CFS requires (paper §3).
//!
//! "all non-discrete features must be discretized. By default, this
//! process is performed using the discretization algorithm proposed by
//! Fayyad and Irani" — [`mdl`] implements that algorithm (entropy-based
//! binary splitting with the MDL stopping criterion). [`equal_width`] is a
//! simple fallback used by tests and ablations.
//!
//! Discretization is applied identically before every algorithm variant
//! (sequential, hp, vp) so the equivalence invariant is over the same
//! binned data — matching the paper, whose measurements are of the CFS
//! itself, with discretization as a shared preprocessing step.

pub mod equal_width;
pub mod mdl;

use crate::core::Result;
use crate::data::columnar::{Column, Dataset, DiscreteDataset};

/// Discretize every numeric column with Fayyad–Irani MDL; categorical
/// columns pass through (re-binned only if their arity exceeds
/// [`DiscreteDataset::MAX_BINS`]).
pub fn discretize_dataset(ds: &Dataset) -> Result<DiscreteDataset> {
    let mut cols = Vec::with_capacity(ds.num_features());
    let mut arities = Vec::with_capacity(ds.num_features());
    for col in &ds.features {
        match col {
            Column::Numeric(v) => {
                let cuts = mdl::mdl_cut_points(v, &ds.class, ds.class_arity);
                let (binned, arity) = mdl::apply_cuts(v, &cuts);
                cols.push(binned);
                arities.push(arity);
            }
            Column::Categorical { values, arity } => {
                if *arity <= DiscreteDataset::MAX_BINS {
                    cols.push(values.clone());
                    arities.push((*arity).max(1));
                } else {
                    let (rebinned, new_arity) =
                        cap_arity(values, *arity, DiscreteDataset::MAX_BINS);
                    cols.push(rebinned);
                    arities.push(new_arity);
                }
            }
        }
    }
    DiscreteDataset::new(
        ds.name.clone(),
        cols,
        arities,
        ds.class.clone(),
        ds.class_arity,
    )
}

/// Re-bin a high-arity categorical column to at most `max_bins` values:
/// the `max_bins − 1` most frequent categories keep distinct bins, the
/// tail shares the last bin (the standard "other" bucket).
pub fn cap_arity(values: &[u8], arity: u16, max_bins: u16) -> (Vec<u8>, u16) {
    debug_assert!(arity > max_bins);
    let mut freq: Vec<(u64, u16)> = (0..arity).map(|v| (0u64, v)).collect();
    for &v in values {
        freq[v as usize].0 += 1;
    }
    freq.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    let keep = (max_bins - 1) as usize;
    let mut remap = vec![max_bins - 1; arity as usize];
    for (slot, &(_, val)) in freq.iter().take(keep).enumerate() {
        remap[val as usize] = slot as u16;
    }
    let out = values.iter().map(|&v| remap[v as usize] as u8).collect();
    (out, max_bins)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{kddcup99_like, SynthConfig};

    #[test]
    fn discretize_produces_valid_dataset() {
        let ds = kddcup99_like(&SynthConfig {
            rows: 400,
            seed: 6,
            features: Some(12),
        });
        let dd = discretize_dataset(&ds).unwrap();
        assert_eq!(dd.num_features(), 12);
        assert_eq!(dd.num_rows(), 400);
        for (f, col) in dd.cols.iter().enumerate() {
            let a = dd.arities[f];
            assert!(a >= 1 && a <= DiscreteDataset::MAX_BINS);
            assert!(col.iter().all(|&v| u16::from(v) < a));
        }
    }

    #[test]
    fn cap_arity_keeps_frequent_categories_distinct() {
        // 40 categories, values 0..4 dominate.
        let mut values = Vec::new();
        for _ in 0..100 {
            for v in 0..4u8 {
                values.push(v);
            }
        }
        for v in 4..40u8 {
            values.push(v);
        }
        let (out, arity) = cap_arity(&values, 40, 8);
        assert_eq!(arity, 8);
        assert!(out.iter().all(|&v| v < 8));
        // the four dominant categories map to four distinct bins
        let mut dom_bins: Vec<u8> = (0..400).map(|i| out[i]).collect();
        dom_bins.sort_unstable();
        dom_bins.dedup();
        assert_eq!(dom_bins.len(), 4);
        // tail categories share the overflow bin
        assert!(out[400..].iter().filter(|&&v| v == 7).count() > 20);
    }
}
