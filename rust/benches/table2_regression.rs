//! Regenerates paper Table 2: DiCFS-hp (classification, SU) vs the
//! regression CFS of Eiras-Franco et al. (RegCFS/RegWEKA, Pearson) on the
//! EPSILON/HIGGS size variants, with speed-ups vs the sequential
//! versions.
//!
//! Output: table + `bench_out/table2_regression.csv`.

use dicfs::harness::{bench_scale, table2};

fn main() {
    let scale = bench_scale();
    println!("== Table 2: DiCFS-hp vs RegCFS (scale {scale}) ==\n");
    let rows = table2::run(scale, 10);
    table2::emit(&rows);
}
