//! Ablation for the multi-algorithm substrate (DESIGN.md §17): does
//! serving CFS and mRMR from ONE measure-keyed cache actually save
//! contingency-table work over running each algorithm in isolation?
//!
//! Workload, per tenant dataset:
//! * **isolated** — two cold services: one runs the CFS query, the
//!   other runs the mRMR query. Each computes its own tables.
//! * **shared** — one service runs CFS then mRMR. The mRMR query's MI
//!   terms are *finished* driver-side from the tables the CFS query
//!   already cached, so the shared run must compute **strictly fewer**
//!   fresh contingency tables than the isolated pair (hard assert at
//!   every scale — this is a counting invariant, not a timing one).
//!
//! Every selection in every phase is asserted bit-identical to its
//! sequential reference driver (`SequentialCfs` / `SequentialMrmr` /
//! `SequentialRelieff`) — the equivalence contract of DESIGN.md §17.
//! A ReliefF query rides along on the shared service to price the
//! row-wise member of the family (it touches no pair cache).
//!
//! Output: table + `bench_out/BENCH_multialgo.json`.

use std::sync::Arc;

use dicfs::cfs::best_first::CfsConfig;
use dicfs::cfs::{MrmrConfig, RelieffConfig, SequentialCfs, SequentialMrmr, SequentialRelieff};
use dicfs::data::columnar::DiscreteDataset;
use dicfs::data::synth::{by_name, SynthConfig};
use dicfs::discretize::discretize_dataset;
use dicfs::harness::{bench_scale, report};
use dicfs::serve::{AlgoSpec, DicfsService, QuerySpec, ServeScheme, ServiceConfig};
use dicfs::sparklet::ClusterConfig;
use dicfs::util::chart::table;

struct Tenant {
    name: &'static str,
    scheme: ServeScheme,
    data: Arc<DiscreteDataset>,
}

fn tenants(scale: f64) -> Vec<Tenant> {
    let rows = |base: usize| ((base as f64 * scale) as usize).max(300);
    let mk = |family: &str, r: usize, seed: u64, features: usize| {
        let raw = by_name(
            family,
            &SynthConfig {
                rows: r,
                seed,
                features: Some(features),
            },
        );
        Arc::new(discretize_dataset(&raw).expect("discretize tenant"))
    };
    vec![
        Tenant {
            name: "higgs-hp",
            scheme: ServeScheme::Horizontal,
            data: mk("higgs", rows(2_000), 31, 14),
        },
        Tenant {
            name: "kdd-auto",
            scheme: ServeScheme::Auto,
            data: mk("kddcup99", rows(1_500), 32, 12),
        },
        Tenant {
            name: "eps-seq",
            scheme: ServeScheme::Sequential,
            data: mk("epsilon", rows(1_000), 33, 16),
        },
    ]
}

fn service(nodes: usize) -> DicfsService {
    DicfsService::new(ServiceConfig {
        cluster: ClusterConfig::with_nodes(nodes),
        max_inflight_jobs: 2,
        ..ServiceConfig::default()
    })
}

fn spec(dataset: usize, algo: AlgoSpec) -> QuerySpec {
    QuerySpec {
        dataset,
        cfs: CfsConfig::default(),
        algo,
    }
}

fn main() {
    let scale = bench_scale();
    let tenants = tenants(scale);
    println!("\n=== multi-algorithm substrate ablation (scale {scale}) ===\n");

    let mut rows = Vec::new();
    let mut tenant_json = Vec::new();
    let mut total_iso = 0usize;
    let mut total_shared = 0usize;

    for t in &tenants {
        // Sequential reference drivers: the oracles every phase must
        // match bit-for-bit.
        let cfs_ref = SequentialCfs::default().select_discrete(&t.data);
        let mrmr_ref = SequentialMrmr::new(MrmrConfig::default()).select_discrete(&t.data);
        let relieff_ref = SequentialRelieff::default().select_discrete(&t.data);

        // Isolated: each algorithm pays for its own tables.
        let iso_cfs_svc = service(3);
        let id = iso_cfs_svc.register_discrete(t.name, Arc::clone(&t.data), t.scheme, None);
        let iso_cfs = iso_cfs_svc.query(&spec(id, AlgoSpec::Cfs));
        assert_eq!(iso_cfs.result.selected, cfs_ref.selected, "{}: isolated CFS", t.name);
        let iso_cfs_fresh = iso_cfs_svc.dataset(id).unwrap().cache().fresh_publishes();

        let iso_mrmr_svc = service(3);
        let id = iso_mrmr_svc.register_discrete(t.name, Arc::clone(&t.data), t.scheme, None);
        let iso_mrmr = iso_mrmr_svc.query(&spec(id, AlgoSpec::Mrmr(MrmrConfig::default())));
        assert_eq!(iso_mrmr.result.selected, mrmr_ref.selected, "{}: isolated mRMR", t.name);
        assert_eq!(iso_mrmr.result.merit.to_bits(), mrmr_ref.merit.to_bits());
        let iso_mrmr_fresh = iso_mrmr_svc.dataset(id).unwrap().cache().fresh_publishes();
        let iso_fresh = iso_cfs_fresh + iso_mrmr_fresh;

        // Shared: one substrate, CFS first, then mRMR finishing MI off
        // the cached tables, then ReliefF riding along row-wise.
        let svc = service(3);
        let id = svc.register_discrete(t.name, Arc::clone(&t.data), t.scheme, None);
        let shared_cfs = svc.query(&spec(id, AlgoSpec::Cfs));
        assert_eq!(shared_cfs.result.selected, cfs_ref.selected, "{}: shared CFS", t.name);
        let shared_mrmr = svc.query(&spec(id, AlgoSpec::Mrmr(MrmrConfig::default())));
        assert_eq!(
            shared_mrmr.result.selected, mrmr_ref.selected,
            "{}: shared mRMR",
            t.name
        );
        assert_eq!(shared_mrmr.result.merit.to_bits(), mrmr_ref.merit.to_bits());
        let shared_relieff = svc.query(&spec(id, AlgoSpec::Relieff(RelieffConfig::default())));
        assert_eq!(
            shared_relieff.result.selected, relieff_ref.selected,
            "{}: shared ReliefF",
            t.name
        );
        let report_shared = svc.cache_report(id).unwrap();
        let shared_fresh = svc.dataset(id).unwrap().cache().fresh_publishes();

        // The tentpole claim: strictly fewer fresh contingency tables
        // than the isolated pair of runs.
        assert!(
            shared_fresh < iso_fresh,
            "{}: shared substrate computed {shared_fresh} fresh tables, \
             isolated runs computed {iso_fresh} — sharing saved nothing",
            t.name
        );
        assert!(
            report_shared.cross_measure_finishes > 0,
            "{}: no MI term was finished from a cached SU table",
            t.name
        );

        total_iso += iso_fresh;
        total_shared += shared_fresh;
        rows.push(vec![
            t.name.to_string(),
            t.scheme.label().to_string(),
            iso_cfs_fresh.to_string(),
            iso_mrmr_fresh.to_string(),
            shared_fresh.to_string(),
            (iso_fresh - shared_fresh).to_string(),
            report_shared.cross_measure_finishes.to_string(),
            format!("{:.1}%", 100.0 * (1.0 - shared_fresh as f64 / iso_fresh as f64)),
        ]);
        tenant_json.push(format!(
            "{{\"name\":\"{}\",\"scheme\":\"{}\",\"fresh_isolated_cfs\":{iso_cfs_fresh},\
             \"fresh_isolated_mrmr\":{iso_mrmr_fresh},\"fresh_shared\":{shared_fresh},\
             \"cross_measure_finishes\":{},\"selections_bit_identical\":true}}",
            t.name,
            t.scheme.label(),
            report_shared.cross_measure_finishes
        ));
    }

    println!(
        "{}",
        table(
            &[
                "tenant", "scheme", "fresh cfs", "fresh mrmr", "fresh shared", "saved",
                "mi finishes", "saving",
            ],
            &rows
        )
    );
    println!(
        "fresh contingency tables: isolated {total_iso} vs shared {total_shared} \
         (saved {})",
        total_iso - total_shared
    );

    let json = format!(
        "{{\"scale\":{scale},\"fresh_isolated_total\":{total_iso},\
         \"fresh_shared_total\":{total_shared},\"tenants\":[{}]}}\n",
        tenant_json.join(",")
    );
    let path = report::out_dir().join("BENCH_multialgo.json");
    std::fs::write(&path, json).expect("write BENCH_multialgo.json");
    println!("  data: {}\n", path.display());
}
