//! Hand-rolled property tests (proptest is not vendored in this
//! environment): randomized invariants over the numeric core, the
//! discretizer, the cache, and the scheduler — seeded, many iterations,
//! shrink-free but reproducible.

use std::sync::Arc;

use dicfs::cfs::SequentialCfs;
use dicfs::correlation::cache::CorrelationCache;
use dicfs::correlation::ctable::ContingencyTable;
use dicfs::correlation::entropy::entropies;
use dicfs::correlation::pearson::PearsonStats;
use dicfs::correlation::su::{su_from_table, symmetrical_uncertainty};
use dicfs::data::columnar::DiscreteDataset;
use dicfs::dicfs::{DiCfs, DiCfsConfig, Partitioning};
use dicfs::discretize::mdl::{apply_cuts, mdl_cut_points};
use dicfs::sparklet::metrics::lpt_makespan;
use dicfs::util::XorShift64Star;

fn random_column(rng: &mut XorShift64Star, n: usize, bins: u16) -> Vec<u8> {
    (0..n).map(|_| rng.next_below(bins as u64) as u8).collect()
}

#[test]
fn prop_su_symmetry_range_and_identity() {
    let mut rng = XorShift64Star::new(101);
    for _ in 0..200 {
        let n = 20 + rng.next_below(400) as usize;
        let bx = 2 + rng.next_below(14) as u16;
        let by = 2 + rng.next_below(14) as u16;
        let x = random_column(&mut rng, n, bx);
        let y = random_column(&mut rng, n, by);
        let su_xy = symmetrical_uncertainty(&x, bx, &y, by);
        let su_yx = symmetrical_uncertainty(&y, by, &x, bx);
        // symmetry (to fp tolerance — summation order differs)
        assert!((su_xy - su_yx).abs() < 1e-10);
        // range
        assert!((0.0..=1.0 + 1e-9).contains(&su_xy), "su={su_xy}");
        // self-correlation of a non-constant column is 1
        if x.iter().any(|&v| v != x[0]) {
            let su_xx = symmetrical_uncertainty(&x, bx, &x, bx);
            assert!((su_xx - 1.0).abs() < 1e-9);
        }
    }
}

#[test]
fn prop_entropy_information_inequalities() {
    let mut rng = XorShift64Star::new(103);
    for _ in 0..200 {
        let n = 10 + rng.next_below(300) as usize;
        let bx = 2 + rng.next_below(8) as u16;
        let by = 2 + rng.next_below(8) as u16;
        let t = ContingencyTable::from_columns(
            &random_column(&mut rng, n, bx),
            bx,
            &random_column(&mut rng, n, by),
            by,
        );
        let (hx, hy, hxy) = entropies(&t);
        // joint bounds: max(H(X), H(Y)) ≤ H(X,Y) ≤ H(X)+H(Y)
        assert!(hxy + 1e-9 >= hx.max(hy), "{hxy} vs {hx},{hy}");
        assert!(hxy <= hx + hy + 1e-9);
        // entropy bounds: 0 ≤ H ≤ log2(bins)
        assert!(hx >= -1e-12 && hx <= f64::from(bx).log2() + 1e-9);
        assert!(hy >= -1e-12 && hy <= f64::from(by).log2() + 1e-9);
    }
}

#[test]
fn prop_ctable_merge_associative_commutative() {
    let mut rng = XorShift64Star::new(107);
    for _ in 0..100 {
        let n = 90 + rng.next_below(300) as usize;
        let bins = 2 + rng.next_below(10) as u16;
        let x = random_column(&mut rng, n, bins);
        let y = random_column(&mut rng, n, bins);
        // three random split points
        let mut cuts: Vec<usize> = (0..2).map(|_| rng.next_below(n as u64) as usize).collect();
        cuts.push(0);
        cuts.push(n);
        cuts.sort_unstable();
        let parts: Vec<ContingencyTable> = cuts
            .windows(2)
            .map(|w| ContingencyTable::from_columns_range(&x, bins, &y, bins, w[0]..w[1]))
            .collect();
        // merge in forward and reverse orders
        let mut fwd = ContingencyTable::new(bins, bins);
        for p in &parts {
            fwd.merge(p).unwrap();
        }
        let mut rev = ContingencyTable::new(bins, bins);
        for p in parts.iter().rev() {
            rev.merge(p).unwrap();
        }
        let whole = ContingencyTable::from_columns(&x, bins, &y, bins);
        assert_eq!(fwd, whole);
        assert_eq!(rev, whole);
        // SU from merged == SU from whole, exactly
        assert_eq!(su_from_table(&fwd), su_from_table(&whole));
    }
}

#[test]
fn prop_pearson_merge_and_invariance() {
    let mut rng = XorShift64Star::new(109);
    for _ in 0..100 {
        let n = 30 + rng.next_below(200) as usize;
        let x: Vec<f32> = (0..n).map(|_| rng.next_gaussian() as f32).collect();
        let y: Vec<f32> = x
            .iter()
            .map(|v| v * 0.5 + rng.next_gaussian() as f32)
            .collect();
        let whole = PearsonStats::from_slices(&x, &y);
        let k = 1 + rng.next_below((n - 1) as u64) as usize;
        let mut merged = PearsonStats::from_slices(&x[..k], &y[..k]);
        merged.merge(&PearsonStats::from_slices(&x[k..], &y[k..]));
        assert!((whole.correlation() - merged.correlation()).abs() < 1e-9);
        // |r| ≤ 1 and correlation is scale-invariant
        let scaled: Vec<f32> = x.iter().map(|v| v * 3.0 + 7.0).collect();
        let r1 = PearsonStats::from_slices(&x, &y).correlation();
        let r2 = PearsonStats::from_slices(&scaled, &y).correlation();
        assert!((r1 - r2).abs() < 1e-6, "{r1} vs {r2}");
    }
}

#[test]
fn prop_mdl_cuts_partition_the_range() {
    let mut rng = XorShift64Star::new(113);
    for _ in 0..60 {
        let n = 100 + rng.next_below(500) as usize;
        let sep = rng.next_range(0.0, 3.0);
        let class: Vec<u8> = (0..n).map(|_| rng.next_below(3) as u8).collect();
        let values: Vec<f32> = class
            .iter()
            .map(|&c| (f64::from(c) * sep + rng.next_gaussian()) as f32)
            .collect();
        let cuts = mdl_cut_points(&values, &class, 3);
        // sorted, distinct, within the data range
        for w in cuts.windows(2) {
            assert!(w[0] < w[1]);
        }
        if let (Some(first), Some(last)) = (cuts.first(), cuts.last()) {
            let lo = values.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = values.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            assert!(*first >= lo && *last <= hi);
        }
        // binning is total and within arity
        let (bins, arity) = apply_cuts(&values, &cuts);
        assert_eq!(arity as usize, cuts.len() + 1);
        assert!(arity <= 32);
        assert!(bins.iter().all(|&b| u16::from(b) < arity));
        // bins are monotone in the value
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| values[a].partial_cmp(&values[b]).unwrap());
        for w in idx.windows(2) {
            assert!(bins[w[0]] <= bins[w[1]]);
        }
    }
}

#[test]
fn prop_cache_single_flight_per_pair() {
    let mut rng = XorShift64Star::new(127);
    for _ in 0..50 {
        let mut cache = CorrelationCache::new();
        let universe = 2 + rng.next_below(20) as usize;
        let mut total_computed = 0usize;
        for _batch in 0..10 {
            let len = 1 + rng.next_below(30) as usize;
            let pairs: Vec<(usize, usize)> = (0..len)
                .map(|_| {
                    let a = rng.next_below(universe as u64) as usize;
                    let b = rng.next_below(universe as u64) as usize;
                    (a, b)
                })
                .collect();
            let vals = cache.get_or_compute_batch(&pairs, |missing| {
                total_computed += missing.len();
                missing.iter().map(|&(a, b)| (a * 31 + b) as f64).collect()
            });
            // returned values always match the canonical computation
            for (&(a, b), v) in pairs.iter().zip(&vals) {
                let (ca, cb) = dicfs::core::pair_key(a, b);
                assert_eq!(*v, (ca * 31 + cb) as f64);
            }
        }
        // no pair computed twice
        assert_eq!(cache.stats().computed, total_computed);
        assert_eq!(cache.len(), total_computed);
        assert!(cache.stats().requested >= cache.stats().computed + cache.stats().hits);
    }
}

#[test]
fn prop_lpt_bounds() {
    let mut rng = XorShift64Star::new(131);
    for _ in 0..100 {
        let n = 1 + rng.next_below(60) as usize;
        let slots = 1 + rng.next_below(16) as usize;
        let tasks: Vec<f64> = (0..n).map(|_| rng.next_range(0.001, 1.0)).collect();
        let total: f64 = tasks.iter().sum();
        let maxt = tasks.iter().cloned().fold(0.0, f64::max);
        let makespan = lpt_makespan(&tasks, slots);
        // lower bounds: perfect parallelism and the longest task
        assert!(makespan + 1e-9 >= total / slots as f64);
        assert!(makespan + 1e-9 >= maxt);
        // upper bound: LPT is within (4/3 − 1/3m) of optimal ≤ lower bounds
        let lower = (total / slots as f64).max(maxt);
        assert!(makespan <= lower * 4.0 / 3.0 + 1e-9, "{makespan} vs {lower}");
        // never worse than serial
        assert!(makespan <= total + 1e-9);
    }
}

#[test]
fn prop_exactness_seq_hp_vp_auto_across_shapes_and_partitions() {
    // The paper's exactness claim, as a property: on random datasets
    // across shapes — tall, wide, and degenerate (single-bin column,
    // plus partition counts exceeding rows/features so empty partitions
    // occur) — sequential ≡ hp ≡ vp ≡ auto, bit-identically, for every
    // partition count 1..8.
    let mut rng = XorShift64Star::new(0x5EED);
    // (rows, features): tall, wide, tiny/degenerate
    let shapes = [(240usize, 5usize), (30, 14), (9, 3)];
    for (round, &(rows, features)) in shapes.iter().enumerate() {
        let mut cols = Vec::with_capacity(features);
        let mut arities = Vec::with_capacity(features);
        for f in 0..features {
            if f == 1 {
                // degenerate single-bin column in every dataset
                cols.push(vec![0u8; rows]);
                arities.push(1u16);
            } else {
                let arity = 2 + rng.next_below(6) as u16;
                cols.push((0..rows).map(|_| rng.next_below(arity as u64) as u8).collect());
                arities.push(arity);
            }
        }
        let class: Vec<u8> = (0..rows).map(|_| rng.next_below(3) as u8).collect();
        let dd = Arc::new(
            DiscreteDataset::new(format!("prop-{round}"), cols, arities, class, 3).unwrap(),
        );
        let seq = SequentialCfs::default().select_discrete(&dd);
        for parts in 1..=8usize {
            for scheme in [
                Partitioning::Horizontal,
                Partitioning::Vertical,
                Partitioning::Auto,
            ] {
                let mut cfg = DiCfsConfig::for_scheme(scheme, 3);
                cfg.num_partitions = Some(parts);
                let run = DiCfs::native(cfg).select(&dd);
                assert_eq!(
                    run.result.selected, seq.selected,
                    "{scheme:?} diverged on shape {rows}x{features} with {parts} partitions"
                );
                assert_eq!(
                    run.result.merit.to_bits(),
                    seq.merit.to_bits(),
                    "{scheme:?} merit not bit-identical on {rows}x{features}/{parts}"
                );
                assert_eq!(
                    run.result.locally_predictive_added,
                    seq.locally_predictive_added
                );
            }
        }
    }
}

#[test]
fn prop_prune_auto_bit_identical_to_exact_across_schemes_engines_shapes() {
    // The sketch-then-verify exactness claim (DESIGN.md §16), as a
    // property: `PruneMode::Auto` must be observationally identical to
    // plain exact expansion (`PruneMode::Off`) — same subset, same
    // merit bits, same iteration count, same locally-predictive
    // additions — across sequential and all distributed schemes, both
    // SU engines, and tall / wide / ultrawide / degenerate shapes.
    // Only the advisory counters may differ, and they must stay
    // consistent: `Off` never sketches or prunes, and pruning a
    // candidate implies sketch cells were paid for.
    use dicfs::cfs::best_first::{CfsConfig, PruneMode};
    use dicfs::core::SelectionResult;
    use dicfs::runtime::{NativeEngine, SuEngine, TiledEngine};

    fn check(auto: &SelectionResult, off: &SelectionResult, what: &str) {
        assert_eq!(auto.selected, off.selected, "{what}: subset diverged");
        assert_eq!(
            auto.merit.to_bits(),
            off.merit.to_bits(),
            "{what}: merit not bit-identical"
        );
        assert_eq!(auto.iterations, off.iterations, "{what}: iteration count");
        assert_eq!(
            auto.locally_predictive_added, off.locally_predictive_added,
            "{what}: post-step diverged"
        );
        assert_eq!(off.pruned_candidates, 0, "{what}: Off pruned");
        assert_eq!(off.sampled_cells, 0, "{what}: Off sketched");
        if auto.pruned_candidates > 0 {
            assert!(auto.sampled_cells > 0, "{what}: pruned without sketching");
        }
    }

    let mut rng = XorShift64Star::new(0x9121_5EED);
    // (rows, features): tall, wide, ultrawide (features ≫ rows; several
    // exact class copies over noise, so the capacity-5 queue cut sits at
    // SU = 1 and the noise envelope provably falls below it — pruning is
    // guaranteed to engage, not just permitted), and tiny/degenerate
    // (too few rows for sketch windows and too few candidates for the
    // gate — pruning must silently fall back to exact expansion).
    let shapes = [(240usize, 10usize), (40, 20), (24, 48), (9, 3)];
    let engines: Vec<Arc<dyn SuEngine>> =
        vec![Arc::new(NativeEngine), Arc::new(TiledEngine::new())];
    let mut pruned_total = 0usize;
    let mut sampled_total = 0u64;

    for (round, &(rows, features)) in shapes.iter().enumerate() {
        let class: Vec<u8> = (0..rows).map(|_| rng.next_below(2) as u8).collect();
        let mut cols = Vec::with_capacity(features);
        let mut arities: Vec<u16> = Vec::with_capacity(features);
        for f in 0..features {
            if f == 1 {
                // degenerate single-bin column in every dataset
                cols.push(vec![0u8; rows]);
                arities.push(1);
            } else if round == 2 && f < 7 {
                // ultrawide round: exact class copies (SU = 1)
                cols.push(class.clone());
                arities.push(2);
            } else {
                let arity = 2 + rng.next_below(6) as u16;
                cols.push((0..rows).map(|_| rng.next_below(arity as u64) as u8).collect());
                arities.push(arity);
            }
        }
        let dd = Arc::new(
            DiscreteDataset::new(format!("prune-{round}"), cols, arities, class, 2).unwrap(),
        );

        let seq = |mode: PruneMode| {
            SequentialCfs::new(CfsConfig {
                prune: mode,
                ..CfsConfig::default()
            })
            .select_discrete(&dd)
        };
        let s_auto = seq(PruneMode::Auto);
        let s_off = seq(PruneMode::Off);
        check(&s_auto, &s_off, &format!("seq {rows}x{features}"));
        pruned_total += s_auto.pruned_candidates;
        sampled_total += s_auto.sampled_cells;

        for parts in [1usize, 3, 6] {
            for scheme in [
                Partitioning::Horizontal,
                Partitioning::Vertical,
                Partitioning::Auto,
            ] {
                for (ei, engine) in engines.iter().enumerate() {
                    let dist = |mode: PruneMode| {
                        let mut cfg = DiCfsConfig::for_scheme(scheme, 3);
                        cfg.num_partitions = Some(parts);
                        cfg.cfs.prune = mode;
                        DiCfs::new(cfg, Arc::clone(engine)).select(&dd).result
                    };
                    let auto = dist(PruneMode::Auto);
                    let off = dist(PruneMode::Off);
                    let what = format!("{scheme:?}/e{ei} {rows}x{features} p={parts}");
                    check(&auto, &off, &what);
                    // Pruned or not, every scheme walks the sequential
                    // trajectory (the existing exactness bar).
                    assert_eq!(auto.selected, s_off.selected, "{what}: vs sequential subset");
                    assert_eq!(
                        auto.merit.to_bits(),
                        s_off.merit.to_bits(),
                        "{what}: vs sequential merit"
                    );
                    pruned_total += auto.pruned_candidates;
                    sampled_total += auto.sampled_cells;
                }
            }
        }
    }
    // The sweep must actually exercise the sketch path — agreement is
    // vacuous if every run declined to sketch or never pruned.
    assert!(sampled_total > 0, "no run ever sketched");
    assert!(pruned_total > 0, "no run ever pruned a candidate");
}

#[test]
fn prop_incremental_append_bit_identical() {
    // The incremental-service exactness bar (DESIGN.md §12), as a
    // property: split each synth family's stream into base + k appends
    // (k in 1..4), replay register → query → (append → query)^k against
    // one service, and require after every append that (a) the selected
    // subset and merit are bit-identical to a from-scratch sequential
    // run over the merged prefix, and (b) every cached SU entry equals
    // the direct SU over exactly the row prefix it covers. Partition
    // counts 1..8 and all four serve schemes are swept across the
    // (family, k) grid.
    use dicfs::cfs::best_first::CfsConfig;
    use dicfs::correlation::su::symmetrical_uncertainty;
    use dicfs::discretize::discretize_dataset;
    use dicfs::serve::{DicfsService, QuerySpec, ServeScheme, ServiceConfig};
    use dicfs::sparklet::ClusterConfig;

    let mut rng = XorShift64Star::new(0xD17A5EED);
    let families = ["higgs", "kddcup99", "epsilon"];
    let schemes = [
        ServeScheme::Horizontal,
        ServeScheme::Vertical,
        ServeScheme::Auto,
        ServeScheme::Sequential,
    ];
    for (fi, family) in families.iter().enumerate() {
        for k in 1..=4usize {
            let partitions = 1 + (fi * 4 + k * 3) % 8; // covers 1..8 across the grid
            let scheme = schemes[(fi + k) % schemes.len()];
            let total = 240 + rng.next_below(160) as usize;
            let raw = dicfs::data::synth::by_name(
                family,
                &dicfs::data::synth::SynthConfig {
                    rows: total,
                    seed: rng.next_u64(),
                    features: Some(6),
                },
            );
            let full = Arc::new(discretize_dataset(&raw).unwrap());

            // k+1 random, strictly increasing cut points → base + k
            // non-empty deltas.
            let mut cuts: Vec<usize> = (0..k)
                .map(|i| {
                    let lo = (i + 1) * total / (k + 2);
                    lo + rng.next_below((total / (k + 2)) as u64) as usize
                })
                .collect();
            cuts.insert(0, total / (k + 2));
            cuts.push(total);
            cuts.sort_unstable();
            cuts.dedup();

            let service = DicfsService::new(ServiceConfig {
                cluster: ClusterConfig::with_nodes(3),
                max_inflight_jobs: 2,
                ..ServiceConfig::default()
            });
            let id = service.register_discrete(
                &format!("{family}-{k}"),
                Arc::new(full.slice_rows(0..cuts[0])),
                scheme,
                Some(partitions),
            );
            let spec = QuerySpec {
                dataset: id,
                cfs: CfsConfig::default(),
                algo: Default::default(),
            };
            let _ = service.query(&spec);

            for j in 0..cuts.len() - 1 {
                service
                    .append_discrete(id, &full.slice_rows(cuts[j]..cuts[j + 1]))
                    .unwrap();
                let r = service.query(&spec);
                let prefix = full.slice_rows(0..cuts[j + 1]);
                let scratch = dicfs::cfs::SequentialCfs::default().select_discrete(&prefix);
                assert_eq!(
                    r.result.selected, scratch.selected,
                    "{family} k={k} {scheme:?} p={partitions}: subset diverged after append {j}"
                );
                assert_eq!(
                    r.result.merit.to_bits(),
                    scratch.merit.to_bits(),
                    "{family} k={k} {scheme:?} p={partitions}: merit not bit-identical"
                );
            }

            // The cached SU matrix is exact at whatever prefix each
            // entry covers (entries lag only when no query touched them
            // after the last append).
            for ((a, b), rows, _m, su) in service.dataset(id).unwrap().cache().snapshot() {
                let prefix = full.slice_rows(0..rows);
                let (x, bx) = prefix.column(a);
                let (y, by) = prefix.column(b);
                assert_eq!(
                    su.to_bits(),
                    symmetrical_uncertainty(x, bx, y, by).to_bits(),
                    "{family} k={k}: cached SU for {:?} at {rows} rows drifted",
                    (a, b)
                );
            }
        }
    }
}

#[test]
fn prop_engine_axis_bit_identical_tables_su_and_merits() {
    // The engine axis of the exactness claim: every `SuEngine` builds
    // identical contingency tables, and the tiled engine's SU (and the
    // merits of a whole selection run) is bit-identical to native.
    // Swept across tall/wide/degenerate shapes, ragged batch sizes
    // around the tile width P, random row subranges, and arities whose
    // table straddles the bin budget B — both the default engine
    // (40 × 40 = 1600 > 1024) and a tiny-tile engine where 9 × 9
    // already overflows B = 64, so oversize pairs take the scalar
    // fallback inside otherwise-tiled batches. PJRT, when built with
    // artifacts present, is held to exact tables and 1e-5 SU (its SU
    // finish runs in f32).
    use dicfs::runtime::{ColumnPair, NativeEngine, SuEngine, TiledEngine};

    let mut rng = XorShift64Star::new(0x7E57_71ED);
    let native = NativeEngine;
    #[allow(unused_mut)]
    let mut engines: Vec<(&str, Arc<dyn SuEngine>, bool)> = vec![
        ("tiled", Arc::new(TiledEngine::new()) as Arc<dyn SuEngine>, true),
        ("tiled-3x17x64", Arc::new(TiledEngine::with_tiles(3, 17, 64)), true),
    ];
    #[cfg(feature = "pjrt")]
    {
        let dir = dicfs::runtime::artifacts::Registry::default_dir();
        if dir.join("manifest.tsv").exists() {
            engines.push((
                "pjrt",
                Arc::new(dicfs::runtime::pjrt::PjrtEngine::new(&dir).unwrap()),
                false,
            ));
        }
    }

    // (rows, features): tall, wide, tiny/degenerate.
    for &(rows, features) in &[(400usize, 6usize), (24, 15), (8, 3)] {
        let mut cols = Vec::with_capacity(features);
        let mut arities: Vec<u16> = Vec::with_capacity(features);
        for f in 0..features {
            let arity: u16 = match f % 4 {
                0 => 2 + rng.next_below(6) as u16,
                1 => 1,  // degenerate single-bin column
                2 => 40, // 40 × 40 tables straddle the default B
                _ => 9,  // 9 × 9 straddles the tiny-tile B
            };
            cols.push(random_column(&mut rng, rows, arity));
            arities.push(arity);
        }

        // Kernel level: ragged batches over random column pairs and a
        // random row subrange each.
        for &batch in &[1usize, 2, 7, 8, 9, 13] {
            let idx: Vec<(usize, usize)> = (0..batch)
                .map(|_| {
                    (
                        rng.next_below(features as u64) as usize,
                        rng.next_below(features as u64) as usize,
                    )
                })
                .collect();
            let pairs: Vec<ColumnPair<'_>> = idx
                .iter()
                .map(|&(a, b)| ColumnPair {
                    x: &cols[a],
                    bins_x: arities[a],
                    y: &cols[b],
                    bins_y: arities[b],
                })
                .collect();
            let lo = rng.next_below(rows as u64) as usize;
            let hi = lo + rng.next_below((rows - lo + 1) as u64) as usize;
            let base_tables = native.ctables(&pairs, lo..hi);
            let refs: Vec<&ContingencyTable> = base_tables.iter().collect();
            let base_su = native.su_from_tables(&refs);
            let base_fused = native.su_from_column_pairs(&pairs);
            for (name, engine, exact) in &engines {
                assert_eq!(
                    engine.ctables(&pairs, lo..hi),
                    base_tables,
                    "{name}: tables diverged on {rows}x{features} batch {batch} rows {lo}..{hi}"
                );
                let su = engine.su_from_tables(&refs);
                let fused = engine.su_from_column_pairs(&pairs);
                for i in 0..batch {
                    if *exact {
                        assert_eq!(su[i].to_bits(), base_su[i].to_bits(), "{name}: SU bits");
                        assert_eq!(fused[i].to_bits(), base_fused[i].to_bits(), "{name}: fused");
                    } else {
                        assert!((su[i] - base_su[i]).abs() < 1e-5, "{name}: SU drifted");
                        assert!((fused[i] - base_fused[i]).abs() < 1e-5, "{name}: fused");
                    }
                }
            }
        }

        // Merit level: a whole selection run through each bit-exact
        // engine matches the native run bit-for-bit.
        let class: Vec<u8> = (0..rows).map(|_| rng.next_below(3) as u8).collect();
        let dd = Arc::new(
            DiscreteDataset::new(
                format!("engines-{rows}x{features}"),
                cols.clone(),
                arities.clone(),
                class,
                3,
            )
            .unwrap(),
        );
        let base = DiCfs::native(DiCfsConfig::for_scheme(Partitioning::Auto, 3)).select(&dd);
        for (name, engine, exact) in &engines {
            if !*exact {
                continue;
            }
            let run = DiCfs::new(
                DiCfsConfig::for_scheme(Partitioning::Auto, 3),
                Arc::clone(engine),
            )
            .select(&dd);
            assert_eq!(run.result.selected, base.result.selected, "{name}: subset");
            assert_eq!(
                run.result.merit.to_bits(),
                base.result.merit.to_bits(),
                "{name}: merit not bit-identical on {rows}x{features}"
            );
        }
    }
}

#[test]
fn prop_oversize_preserves_column_content() {
    let mut rng = XorShift64Star::new(137);
    for _ in 0..30 {
        let rows = 50 + rng.next_below(200) as usize;
        let ds = dicfs::data::synth::by_name(
            "kddcup99",
            &dicfs::data::synth::SynthConfig {
                rows,
                seed: rng.next_u64(),
                features: Some(6),
            },
        );
        let pct = 110 + rng.next_below(290) as usize;
        let big = dicfs::data::oversize::scale_instances(&ds, pct);
        let target = (rows * pct).div_ceil(100);
        assert_eq!(big.num_rows(), target);
        for r in 0..big.num_rows() {
            assert_eq!(big.class[r], ds.class[r % rows]);
        }
    }
}

#[test]
fn prop_eviction_bit_identical() {
    // The bounded-memory axis of the exactness claim: a budgeted
    // service — any budget, down to a single entry and to zero bytes —
    // selects the same features with the same merit bits as an
    // unbounded one, across serve schemes and engine pools, while its
    // resident bytes never exceed the budget and the cache's recompute
    // accounting balances (`fresh_publishes == len + evicted_pairs`,
    // and every fresh publish was a pair some query computed).
    use dicfs::cfs::best_first::CfsConfig;
    use dicfs::correlation::cache::ENTRY_OVERHEAD_BYTES;
    use dicfs::discretize::discretize_dataset;
    use dicfs::runtime::{NativeEngine, SuEngine, TiledEngine};
    use dicfs::serve::{
        worst_case_cache_bytes, CacheBudget, DicfsService, QuerySpec, RegisterOptions,
        ServeScheme, ServiceConfig,
    };
    use dicfs::sparklet::ClusterConfig;

    let mut rng = XorShift64Star::new(0xE71C_BAD5);
    let schemes = [
        ServeScheme::Horizontal,
        ServeScheme::Vertical,
        ServeScheme::Auto,
        ServeScheme::Sequential,
    ];
    let pools: [fn() -> Vec<Arc<dyn SuEngine>>; 2] = [
        || vec![Arc::new(NativeEngine)],
        || vec![Arc::new(NativeEngine), Arc::new(TiledEngine::new())],
    ];
    let families = ["higgs", "kddcup99", "epsilon"];
    let cfs_mix = [
        CfsConfig::default(),
        CfsConfig {
            max_fails: 3,
            ..CfsConfig::default()
        },
        CfsConfig {
            locally_predictive: false,
            ..CfsConfig::default()
        },
    ];

    for (si, &scheme) in schemes.iter().enumerate() {
        for (pi, pool) in pools.iter().enumerate() {
            let family = families[(si + pi) % families.len()];
            let rows = 240 + rng.next_below(160) as usize;
            let raw = dicfs::data::synth::by_name(
                family,
                &dicfs::data::synth::SynthConfig {
                    rows,
                    seed: rng.next_u64(),
                    features: Some(6),
                },
            );
            let dd = Arc::new(discretize_dataset(&raw).unwrap());
            let worst = worst_case_cache_bytes(&dd);

            // Reference: same scheme/pool, unbounded cache.
            let reference = |budget: CacheBudget| {
                let svc = DicfsService::with_engine_pool(
                    ServiceConfig {
                        cluster: ClusterConfig::with_nodes(3),
                        max_inflight_jobs: 2,
                        ..ServiceConfig::default()
                    },
                    pool(),
                );
                let id = svc
                    .try_register_discrete(
                        family,
                        Arc::clone(&dd),
                        scheme,
                        RegisterOptions {
                            partitions: None,
                            budget,
                            weight: 1.0,
                        },
                    )
                    .unwrap();
                let reports: Vec<_> = cfs_mix
                    .iter()
                    .map(|&cfs| {
                        svc.query(&QuerySpec {
                            dataset: id,
                            cfs,
                            algo: Default::default(),
                        })
                    })
                    .collect();
                (svc, id, reports)
            };
            let (_ref_svc, _, unbounded) = reference(CacheBudget::Unbounded);

            // Budgets: pathological zero, ~one entry, a quarter of the
            // worst case, and a random point in (0, worst).
            let budgets = [
                0usize,
                ENTRY_OVERHEAD_BYTES + 16 * 16 * 8,
                worst / 4,
                1 + rng.next_below(worst as u64) as usize,
            ];
            for &budget in &budgets {
                let (svc, id, bounded) = reference(CacheBudget::Bytes(budget));
                for (u, b) in unbounded.iter().zip(&bounded) {
                    assert_eq!(
                        b.result.selected, u.result.selected,
                        "{scheme:?} pool{pi} budget={budget}: subset diverged"
                    );
                    assert_eq!(
                        b.result.merit.to_bits(),
                        u.result.merit.to_bits(),
                        "{scheme:?} pool{pi} budget={budget}: merit not bit-identical"
                    );
                    // Identical trajectory: the searches requested the
                    // same number of pairs; the budget only changes how
                    // many were recomputed rather than served as hits.
                    assert_eq!(b.cache.requested, u.cache.requested);
                }

                let reg = svc.dataset(id).unwrap();
                let cache = reg.cache();
                assert_eq!(cache.budget(), Some(budget));
                assert!(
                    cache.resident_bytes() <= budget,
                    "{scheme:?} budget={budget}: resident {} over budget",
                    cache.resident_bytes()
                );
                assert!(
                    cache.peak_resident_bytes() <= budget,
                    "{scheme:?} budget={budget}: peak {} over budget",
                    cache.peak_resident_bytes()
                );
                // Recompute accounting balances exactly: every fresh
                // publish is either still resident or was evicted, and
                // the queries' computed counters funded every fresh
                // publish (queries ran one at a time, so no publish was
                // a concurrent overwrite).
                assert_eq!(
                    cache.fresh_publishes(),
                    cache.len() + cache.evicted_pairs(),
                    "{scheme:?} budget={budget}: publish/evict ledger unbalanced"
                );
                let computed: usize = bounded.iter().map(|r| r.cache.computed).sum();
                assert_eq!(
                    computed,
                    cache.fresh_publishes(),
                    "{scheme:?} budget={budget}: computed pairs != fresh publishes"
                );
                // A budget below the working set must actually evict.
                if budget < worst / 8 {
                    assert!(
                        cache.evicted_pairs() > 0,
                        "{scheme:?} budget={budget}: tiny budget never evicted"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_multialgo_substrate() {
    // The measure-keyed substrate (DESIGN.md §17): every selector of the
    // family is served from ONE contingency-table cache per dataset.
    // Axes: serve scheme (seq/hp/vp/auto) × engine pool (native /
    // native+tiled) × synthetic shape. Invariants:
    // (a) CFS / mRMR / ReliefF selections through the service are
    //     bit-identical to their sequential reference drivers;
    // (b) the mRMR query *finished* MI off tables the CFS query already
    //     cached (cross-measure reuse actually happened);
    // (c) every cached (measure, value) — SU and MI alike, whichever
    //     engine built the table — is bit-identical to a direct driver
    //     computation on the raw columns.
    use dicfs::cfs::best_first::CfsConfig;
    use dicfs::cfs::{MrmrConfig, RelieffConfig, SequentialMrmr, SequentialRelieff};
    use dicfs::correlation::{mutual_information, Measure};
    use dicfs::discretize::discretize_dataset;
    use dicfs::runtime::{NativeEngine, SuEngine, TiledEngine};
    use dicfs::serve::{AlgoSpec, DicfsService, QuerySpec, ServeScheme, ServiceConfig};
    use dicfs::sparklet::ClusterConfig;

    let mut rng = XorShift64Star::new(0xA160_5EED);
    let schemes = [
        ServeScheme::Sequential,
        ServeScheme::Horizontal,
        ServeScheme::Vertical,
        ServeScheme::Auto,
    ];
    let pools: [fn() -> Vec<Arc<dyn SuEngine>>; 2] = [
        || vec![Arc::new(NativeEngine)],
        || vec![Arc::new(NativeEngine), Arc::new(TiledEngine::new())],
    ];
    let families = ["higgs", "kddcup99", "epsilon"];

    for &scheme in &schemes {
        for (pi, pool) in pools.iter().enumerate() {
            for family in families {
                let rows = 200 + rng.next_below(120) as usize;
                let raw = dicfs::data::synth::by_name(
                    family,
                    &dicfs::data::synth::SynthConfig {
                        rows,
                        seed: rng.next_u64(),
                        features: Some(6),
                    },
                );
                let dd = Arc::new(discretize_dataset(&raw).unwrap());

                // Sequential reference drivers on the same discrete data.
                let cfs_oracle = SequentialCfs::default().select_discrete(&dd);
                let mrmr_oracle = SequentialMrmr::new(MrmrConfig::default()).select_discrete(&dd);
                let relieff_oracle =
                    SequentialRelieff::new(RelieffConfig::default()).select_discrete(&dd);

                let svc = DicfsService::with_engine_pool(
                    ServiceConfig {
                        cluster: ClusterConfig::with_nodes(3),
                        max_inflight_jobs: 2,
                        ..ServiceConfig::default()
                    },
                    pool(),
                );
                let id = svc.register_discrete(family, Arc::clone(&dd), scheme, None);

                // CFS warms the table cache under SU…
                let cfs = svc.query(&QuerySpec {
                    dataset: id,
                    cfs: CfsConfig::default(),
                    algo: AlgoSpec::Cfs,
                });
                assert_eq!(
                    cfs.result.selected, cfs_oracle.selected,
                    "{family} {scheme:?} pool{pi}: CFS diverged from the sequential driver"
                );

                // …then mRMR finishes MI off the very same tables.
                let mrmr = svc.query(&QuerySpec {
                    dataset: id,
                    cfs: CfsConfig::default(),
                    algo: AlgoSpec::Mrmr(MrmrConfig::default()),
                });
                assert_eq!(
                    mrmr.result.selected, mrmr_oracle.selected,
                    "{family} {scheme:?} pool{pi}: mRMR diverged from the sequential driver"
                );
                assert_eq!(
                    mrmr.result.merit.to_bits(),
                    mrmr_oracle.merit.to_bits(),
                    "{family} {scheme:?} pool{pi}: mRMR merit not bit-identical"
                );

                let relieff = svc.query(&QuerySpec {
                    dataset: id,
                    cfs: CfsConfig::default(),
                    algo: AlgoSpec::Relieff(RelieffConfig::default()),
                });
                assert_eq!(
                    relieff.result.selected, relieff_oracle.selected,
                    "{family} {scheme:?} pool{pi}: ReliefF diverged across decompositions"
                );

                let report = svc.cache_report(id).unwrap();
                assert!(
                    report.cross_measure_finishes > 0,
                    "{family} {scheme:?} pool{pi}: mRMR never reused a CFS table"
                );

                let (mut saw_su, mut saw_mi) = (false, false);
                for ((a, b), nrows, m, v) in svc.dataset(id).unwrap().cache().snapshot() {
                    assert_eq!(nrows, dd.num_rows());
                    let (x, bx) = dd.column(a);
                    let (y, by) = dd.column(b);
                    let direct = match m {
                        Measure::Su => {
                            saw_su = true;
                            symmetrical_uncertainty(x, bx, y, by)
                        }
                        Measure::Mi => {
                            saw_mi = true;
                            mutual_information(x, bx, y, by)
                        }
                        Measure::Pearson => {
                            unreachable!("no Pearson entries in a discrete cache")
                        }
                    };
                    assert_eq!(
                        v.to_bits(),
                        direct.to_bits(),
                        "{family} {scheme:?} pool{pi}: cached {m:?} for {:?} drifted",
                        (a, b)
                    );
                }
                assert!(
                    saw_su && saw_mi,
                    "{family} {scheme:?} pool{pi}: cache missing a measure"
                );
            }
        }
    }
}
