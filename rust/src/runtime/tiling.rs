//! Padding/packing of dynamic batches into the fixed AOT tile shapes.
//!
//! PJRT executables are compiled for static `(P, N, B)` shapes; the
//! coordinator's batches are ragged. This module packs column pairs into
//! `i32[P, N]` buffers with an `f32[N]` validity mask (padding rows are
//! masked out; padding pairs are discarded on output) and contingency
//! tables into `f32[P, B, B]` — matching exactly what
//! `python/compile/aot.py` lowered.

use crate::correlation::ContingencyTable;
use crate::runtime::ColumnPair;

/// One packed ctable-kernel invocation.
#[derive(Debug, Clone)]
pub struct PackedColumns {
    /// `i32[P*N]` row-major first-feature bins.
    pub x: Vec<i32>,
    /// `i32[P*N]` row-major second-feature bins.
    pub y: Vec<i32>,
    /// `f32[N]` validity mask (shared across the pair axis).
    pub valid: Vec<f32>,
    /// How many of the P slots hold real pairs.
    pub live_pairs: usize,
}

/// Pack up to `tile_p` of `pairs` (starting at `offset`) over the logical
/// row window `row_start..row_end`, into a `tile_n`-row tile (rows past
/// the window are masked invalid).
///
/// All pairs in one call must share the same column length.
pub fn pack_columns(
    pairs: &[ColumnPair<'_>],
    offset: usize,
    tile_p: usize,
    row_start: usize,
    row_end: usize,
    tile_n: usize,
) -> PackedColumns {
    let live = (pairs.len() - offset).min(tile_p);
    let n_total = pairs[offset].x.len();
    debug_assert!(row_end <= n_total);
    let mut x = vec![0i32; tile_p * tile_n];
    let mut y = vec![0i32; tile_p * tile_n];
    let live_rows = row_end.saturating_sub(row_start).min(tile_n);
    let mut valid = vec![0f32; tile_n];
    for v in valid.iter_mut().take(live_rows) {
        *v = 1.0;
    }
    for p in 0..live {
        let pair = &pairs[offset + p];
        debug_assert_eq!(pair.x.len(), n_total, "ragged pair batch");
        let xs = &pair.x[row_start..row_start + live_rows];
        let ys = &pair.y[row_start..row_start + live_rows];
        let dst = p * tile_n;
        for (i, (&a, &b)) in xs.iter().zip(ys).enumerate() {
            x[dst + i] = i32::from(a);
            y[dst + i] = i32::from(b);
        }
    }
    PackedColumns {
        x,
        y,
        valid,
        live_pairs: live,
    }
}

/// Pack up to `tile_p` contingency tables (starting at `offset`) into an
/// `f32[P*B*B]` buffer, zero-padding each table into the `B × B` corner.
pub fn pack_tables(
    tables: &[&ContingencyTable],
    offset: usize,
    tile_p: usize,
    tile_b: usize,
) -> (Vec<f32>, usize) {
    let live = (tables.len() - offset).min(tile_p);
    let mut out = vec![0f32; tile_p * tile_b * tile_b];
    for p in 0..live {
        let t = tables[offset + p];
        debug_assert!(
            t.bins_x as usize <= tile_b && t.bins_y as usize <= tile_b,
            "table {}x{} exceeds tile {tile_b}",
            t.bins_x,
            t.bins_y
        );
        let base = p * tile_b * tile_b;
        for bx in 0..t.bins_x as usize {
            for by in 0..t.bins_y as usize {
                out[base + bx * tile_b + by] =
                    t.counts[bx * t.bins_y as usize + by] as f32;
            }
        }
    }
    (out, live)
}

/// Convert one `f32[B, B]` kernel output slab back into a
/// [`ContingencyTable`] of logical shape `bins_x × bins_y` (counts are
/// exact integers ≤ 2²⁴, so the f32 → u64 round-trip is lossless for any
/// partition this system processes).
pub fn unpack_table(slab: &[f32], tile_b: usize, bins_x: u16, bins_y: u16) -> ContingencyTable {
    let mut t = ContingencyTable::new(bins_x, bins_y);
    for bx in 0..bins_x as usize {
        for by in 0..bins_y as usize {
            t.counts[bx * bins_y as usize + by] = slab[bx * tile_b + by].round() as u64;
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair_of<'a>(x: &'a [u8], y: &'a [u8], bins: u16) -> ColumnPair<'a> {
        ColumnPair {
            x,
            bins_x: bins,
            y,
            bins_y: bins,
        }
    }

    #[test]
    fn pack_pads_rows_and_masks() {
        let x = [1u8, 2, 3];
        let y = [3u8, 2, 1];
        let p = pack_columns(&[pair_of(&x, &y, 4)], 0, 2, 0, 3, 8);
        assert_eq!(p.live_pairs, 1);
        assert_eq!(&p.x[..3], &[1, 2, 3]);
        assert_eq!(&p.x[3..8], &[0; 5]); // padded
        assert_eq!(&p.valid[..], &[1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(&p.y[8..16], &[0; 8]); // dead pair slot zeroed
    }

    #[test]
    fn pack_row_window() {
        let x: Vec<u8> = (0..10).map(|i| (i % 4) as u8).collect();
        let p = pack_columns(&[pair_of(&x, &x, 4)], 0, 1, 8, 10, 4);
        // rows 8..10 live, 2 padding
        assert_eq!(&p.x[..2], &[0, 1]);
        assert_eq!(&p.valid[..], &[1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn pack_tables_roundtrip() {
        let t = ContingencyTable::from_columns(&[0, 1, 1, 2], 3, &[1, 0, 1, 1], 2);
        let (buf, live) = pack_tables(&[&t], 0, 4, 8);
        assert_eq!(live, 1);
        let back = unpack_table(&buf[..64], 8, 3, 2);
        assert_eq!(back, t);
    }

    #[test]
    fn pack_tables_multiple_offsets() {
        let a = ContingencyTable::from_columns(&[0, 0], 2, &[1, 1], 2);
        let b = ContingencyTable::from_columns(&[1, 1], 2, &[0, 1], 2);
        let (buf, live) = pack_tables(&[&a, &b], 1, 2, 4);
        assert_eq!(live, 1);
        let back = unpack_table(&buf[..16], 4, 2, 2);
        assert_eq!(back, b);
    }

    #[test]
    fn chunked_pack_covers_all_pairs() {
        let x = [0u8, 1];
        let y = [1u8, 0];
        let pairs: Vec<ColumnPair> = (0..5).map(|_| pair_of(&x, &y, 2)).collect();
        let first = pack_columns(&pairs, 0, 2, 0, 2, 2);
        let last = pack_columns(&pairs, 4, 2, 0, 2, 2);
        assert_eq!(first.live_pairs, 2);
        assert_eq!(last.live_pairs, 1);
    }
}
