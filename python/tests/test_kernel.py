"""Kernel-vs-oracle correctness: the CORE numeric signal of the repo.

The Pallas kernels (interpret=True) must agree with the pure-jnp oracle
(ref.py) and with a hand-rolled numpy recount, across shapes, bin counts,
masks and degenerate inputs. The rust NativeEngine mirrors the same
conventions and is cross-checked against these artifacts in rust tests.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.ctable import ctable_pallas
from compile.kernels.su import ctable_su_pallas, su_pallas


def numpy_ctable(x, y, valid, num_bins):
    """Scatter-increment recount, independent of any jnp code path."""
    p, n = x.shape
    ct = np.zeros((p, num_bins, num_bins), dtype=np.float64)
    for i in range(p):
        for r in range(n):
            if valid[r] > 0:
                ct[i, x[i, r], y[i, r]] += 1.0
    return ct


def numpy_su(ct):
    """Direct entropy recount with python floats."""
    out = []
    for t in np.asarray(ct, dtype=np.float64):
        total = t.sum()
        if total == 0:
            out.append(0.0)
            continue
        pxy = t / total
        px, py = pxy.sum(axis=1), pxy.sum(axis=0)

        def ent(p):
            p = p[p > 0]
            return float(-(p * np.log2(p)).sum())

        hx, hy, hxy = ent(px), ent(py), ent(pxy.ravel())
        out.append(0.0 if hx + hy == 0 else 2.0 * (hx + hy - hxy) / (hx + hy))
    return np.array(out)


def random_case(rng, p, n, num_bins, mask_frac=0.0):
    x = rng.integers(0, num_bins, size=(p, n)).astype(np.int32)
    y = rng.integers(0, num_bins, size=(p, n)).astype(np.int32)
    valid = (rng.random(n) >= mask_frac).astype(np.float32)
    return x, y, valid


class TestCtableKernel:
    @pytest.mark.parametrize("p,n,b,block_n", [(4, 256, 16, 256), (8, 1024, 32, 256),
                                               (1, 512, 4, 128), (32, 2048, 32, 1024)])
    def test_matches_ref_and_numpy(self, p, n, b, block_n):
        rng = np.random.default_rng(7 * p + n + b)
        x, y, valid = random_case(rng, p, n, b, mask_frac=0.2)
        got = np.asarray(ctable_pallas(x, y, valid, num_bins=b, block_n=block_n))
        want_ref = np.asarray(ref.ctable_ref(x, y, valid, b))
        want_np = numpy_ctable(x, y, valid, b)
        np.testing.assert_allclose(got, want_ref, atol=1e-5)
        np.testing.assert_allclose(got, want_np, atol=1e-5)

    def test_counts_sum_to_valid_rows(self):
        rng = np.random.default_rng(0)
        x, y, valid = random_case(rng, 4, 512, 8, mask_frac=0.5)
        ct = np.asarray(ctable_pallas(x, y, valid, num_bins=8, block_n=256))
        np.testing.assert_allclose(ct.sum(axis=(1, 2)), np.full(4, valid.sum()), atol=1e-5)

    def test_all_masked_gives_empty_tables(self):
        x = np.zeros((2, 256), np.int32)
        y = np.zeros((2, 256), np.int32)
        valid = np.zeros(256, np.float32)
        ct = np.asarray(ctable_pallas(x, y, valid, num_bins=4, block_n=128))
        assert ct.sum() == 0.0

    def test_multi_row_tile_accumulation(self):
        # n spans several block_n tiles; the accumulate-over-grid pattern
        # must produce the same result as one big tile.
        rng = np.random.default_rng(3)
        x, y, valid = random_case(rng, 2, 2048, 8)
        big = np.asarray(ctable_pallas(x, y, valid, num_bins=8, block_n=2048))
        tiled = np.asarray(ctable_pallas(x, y, valid, num_bins=8, block_n=256))
        np.testing.assert_allclose(big, tiled, atol=1e-5)

    def test_rejects_non_multiple_block(self):
        x = np.zeros((1, 100), np.int32)
        with pytest.raises(ValueError):
            ctable_pallas(x, x, np.ones(100, np.float32), num_bins=4, block_n=64)


class TestSuKernel:
    def test_matches_ref_and_numpy(self):
        rng = np.random.default_rng(11)
        ct = rng.integers(0, 50, size=(16, 8, 8)).astype(np.float32)
        got = np.asarray(su_pallas(ct))
        np.testing.assert_allclose(got, np.asarray(ref.su_from_ctable_ref(ct)), atol=1e-5)
        np.testing.assert_allclose(got, numpy_su(ct), atol=1e-5)

    def test_identical_features_have_su_one(self):
        # ct diagonal => X == Y deterministically => SU = 1.
        ct = np.zeros((1, 4, 4), np.float32)
        np.fill_diagonal(ct[0], [10, 20, 30, 40])
        np.testing.assert_allclose(np.asarray(su_pallas(ct)), [1.0], atol=1e-6)

    def test_independent_features_have_su_zero(self):
        # Uniform product table => independence => SU = 0.
        ct = np.full((1, 4, 4), 25.0, np.float32)
        np.testing.assert_allclose(np.asarray(su_pallas(ct)), [0.0], atol=1e-6)

    def test_constant_feature_gives_zero(self):
        # All mass in one row AND one column: H(X)+H(Y) == 0 -> SU = 0.
        ct = np.zeros((1, 4, 4), np.float32)
        ct[0, 2, 2] = 100.0
        np.testing.assert_allclose(np.asarray(su_pallas(ct)), [0.0], atol=1e-6)

    def test_empty_table_gives_zero(self):
        ct = np.zeros((3, 8, 8), np.float32)
        np.testing.assert_allclose(np.asarray(su_pallas(ct)), np.zeros(3), atol=0)

    def test_su_range(self):
        rng = np.random.default_rng(13)
        ct = rng.integers(0, 100, size=(64, 16, 16)).astype(np.float32)
        su = np.asarray(su_pallas(ct))
        assert (su >= -1e-6).all() and (su <= 1.0 + 1e-6).all()


class TestFusedKernel:
    def test_matches_unfused_and_ref(self):
        rng = np.random.default_rng(5)
        x, y, valid = random_case(rng, 8, 512, 16, mask_frac=0.3)
        fused = np.asarray(ctable_su_pallas(x, y, valid, num_bins=16, block_n=256))
        unfused = np.asarray(
            su_pallas(ctable_pallas(x, y, valid, num_bins=16, block_n=256))
        )
        want = np.asarray(ref.su_ref(x, y, valid, 16))
        np.testing.assert_allclose(fused, unfused, atol=1e-6)
        np.testing.assert_allclose(fused, want, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(
    p=st.integers(1, 8),
    log_n=st.integers(5, 9),
    b=st.sampled_from([2, 4, 8, 16, 32]),
    mask_frac=st.floats(0.0, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_kernel_equals_oracle(p, log_n, b, mask_frac, seed):
    """Hypothesis sweep: pallas == jnp oracle == numpy recount, any shape."""
    n = 2**log_n
    rng = np.random.default_rng(seed)
    x, y, valid = random_case(rng, p, n, b, mask_frac)
    block_n = min(n, 128)
    ct = np.asarray(ctable_pallas(x, y, valid, num_bins=b, block_n=block_n))
    np.testing.assert_allclose(ct, numpy_ctable(x, y, valid, b), atol=1e-4)
    su = np.asarray(su_pallas(ct))
    np.testing.assert_allclose(su, numpy_su(ct), atol=1e-4)
    assert (su >= -1e-5).all() and (su <= 1 + 1e-5).all()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), b=st.sampled_from([2, 8, 32]))
def test_property_su_symmetry(seed, b):
    """SU(X, Y) == SU(Y, X): transpose the pair inputs, same correlation."""
    rng = np.random.default_rng(seed)
    x, y, valid = random_case(rng, 4, 256, b, 0.1)
    a = np.asarray(ctable_su_pallas(x, y, valid, num_bins=b, block_n=128))
    bb = np.asarray(ctable_su_pallas(y, x, valid, num_bins=b, block_n=128))
    np.testing.assert_allclose(a, bb, atol=1e-5)
