//! DiCFS-vp — vertical partitioning (paper §5.2, after fast-mRMR).
//!
//! Construction performs the *columnar transformation* (paper Fig. 2): a
//! full shuffle that redistributes the dataset by features, so each
//! partition owns whole columns. The class column is broadcast once.
//!
//! Each correlation batch then:
//! 1. picks, per pair, a *reference* side (the class, else the
//!    most-shared feature — in CFS searches this is exactly the paper's
//!    "most recently added feature"),
//! 2. broadcasts the reference columns (the per-step data transmission
//!    the paper lists as disadvantage (ii)),
//! 3. `mapPartitions(localSU)`: the partition owning the non-reference
//!    column builds the complete contingency table and finishes SU
//!    locally (via the engine — the fused L1 kernel under PJRT),
//! 4. collects the scalar SU values (8 bytes each — the upside of vp: no
//!    table shuffle at all).
//!
//! The fixed per-batch cost of broadcasting and the m-partition default
//! are what the paper's §6 experiments probe (EPSILON partition tuning).

use std::collections::HashMap;
use std::ops::Range;
use std::sync::Arc;

use crate::cfs::{Correlator, SharedCorrelator};
use crate::core::{FeatureId, CLASS_ID};
use crate::correlation::sampled::{
    bounds_for_pairs, default_windows, sampled_table, windows_len, SuBounds,
};
use crate::correlation::{ContingencyTable, Marginals};
use crate::data::columnar::DiscreteDataset;
use crate::dicfs::plan::{self, PlanSpec};
use crate::runtime::{ColumnPair, SuEngine};
use crate::sparklet::{Broadcast, Rdd, SparkletContext};

/// Distributed SU correlator over feature partitions.
pub struct VerticalCorrelator {
    data: Arc<DiscreteDataset>,
    engine: Arc<dyn SuEngine>,
    ctx: Arc<SparkletContext>,
    /// Feature ids, hash-distributed by the columnar transformation.
    columns: Rdd<(FeatureId, Vec<u8>)>,
    /// The class column (values + arity), broadcast once at construction;
    /// `localSU` workers read it from here instead of reaching into the
    /// driver-side dataset.
    class_bc: Broadcast<(Vec<u8>, u16)>,
    /// Exact full-column marginal counts for the sampled-bounds finish
    /// (DESIGN.md §16), shared across engine siblings.
    marginals: Arc<Marginals>,
}

impl VerticalCorrelator {
    /// Build via the columnar transformation into `num_partitions`
    /// feature partitions (paper default: one per feature).
    pub fn new(
        ctx: &Arc<SparkletContext>,
        data: Arc<DiscreteDataset>,
        engine: Arc<dyn SuEngine>,
        num_partitions: usize,
    ) -> Self {
        let m = data.num_features();
        let num_partitions = num_partitions.clamp(1, m.max(1));

        // The dataset starts row-partitioned (as Spark reads it); the
        // columnar transformation is a real shuffle of every cell. We
        // model the initial layout as `slots` row-blocks each carrying
        // m column fragments; the reduceByKey concatenation prices the
        // full n×m bytes through the shuffle, like Fig. 2.
        let entries: Vec<(FeatureId, Vec<u8>)> = (0..m).map(|f| (f, data.cols[f].clone())).collect();
        let initial = ctx.parallelize(entries, ctx.cluster.total_slots().min(m).max(1));
        let columns = initial.reduce_by_key(
            "columnarTransformation",
            num_partitions,
            Vec::len, // every cell crosses the wire
            |_a, _b| unreachable!("feature keys are unique"),
        );

        // The class column is broadcast once (every worker needs it for
        // every class-correlation): the actual values plus arity, priced
        // at one byte per row.
        let class_bc = ctx.broadcast((data.class.clone(), data.class_arity), data.class.len());

        Self {
            data,
            engine,
            ctx: Arc::clone(ctx),
            columns,
            class_bc,
            marginals: Arc::new(Marginals::new()),
        }
    }

    /// A sibling correlator over the *same* columnar layout but a
    /// different engine. The columns `Rdd` and class `Broadcast` are
    /// cheap-clone handles, so the columnar transformation shuffle is
    /// paid once and shared by every engine in the planner's pool.
    pub fn with_engine(&self, engine: Arc<dyn SuEngine>) -> Self {
        Self {
            data: Arc::clone(&self.data),
            engine,
            ctx: Arc::clone(&self.ctx),
            columns: self.columns.clone(),
            class_bc: self.class_bc.clone(),
            marginals: Arc::clone(&self.marginals),
        }
    }

    /// Choose the reference (broadcast) side of each pair — delegated to
    /// [`plan::assign_sides`], the single definition both this lowering
    /// and the planner's vp costing share (the broadcast bytes and busy
    /// width of a vp plan are functions of the assignment, so the two
    /// must not drift apart).
    fn assign_sides(pairs: &[(FeatureId, FeatureId)]) -> Vec<(FeatureId, FeatureId)> {
        plan::assign_sides(pairs)
    }

    /// Lower a pair batch to its plan IR (`pair batch → feature layout →
    /// reference broadcast → SU collect`) without running it — what the
    /// adaptive planner prices when deciding hp vs vp. The columnar
    /// layout already exists on this correlator, so the spec carries no
    /// setup charge.
    pub fn plan(&self, pairs: &[(FeatureId, FeatureId)]) -> PlanSpec {
        plan::vp_plan(
            &self.data,
            pairs,
            &self.ctx.cluster,
            self.columns.num_partitions(),
            true,
        )
    }

    /// Per-batch reference assembly shared by the SU batch and the table
    /// job: choose each pair's reference side, broadcast the distinct
    /// non-class reference columns (priced at `ref_rows` bytes each —
    /// the full column for SU batches, only the delta slice for table
    /// jobs), and group pair indices by owner column.
    fn batch_assembly(
        &self,
        pairs: &[(FeatureId, FeatureId)],
        ref_rows: usize,
    ) -> (
        Broadcast<Vec<FeatureId>>,
        Arc<HashMap<FeatureId, Vec<(usize, (FeatureId, FeatureId))>>>,
    ) {
        let sides = Self::assign_sides(pairs);
        let mut ref_ids: Vec<FeatureId> = sides
            .iter()
            .map(|&(_, r)| r)
            .filter(|&r| r != CLASS_ID)
            .collect();
        ref_ids.sort_unstable();
        ref_ids.dedup();
        let ref_bytes = ref_ids.len() * ref_rows;
        let refs_bc = self.ctx.broadcast(ref_ids, ref_bytes);

        // Owner → list of (pair index, original pair). The owner decides
        // *where* the pair is computed; the pair itself is always built
        // in its canonical (a, b) orientation so the result is
        // bit-identical to the sequential/hp computation.
        let mut work: HashMap<FeatureId, Vec<(usize, (FeatureId, FeatureId))>> = HashMap::new();
        for (i, (&(owner, _), &pair)) in sides.iter().zip(pairs).enumerate() {
            work.entry(owner).or_default().push((i, pair));
        }
        (refs_bc, Arc::new(work))
    }

    /// The vp **sampled-sketch job** (DESIGN.md §16): each owner
    /// partition builds its pairs' *sampled* contingency tables — the
    /// deterministic window subsample, counted through the same
    /// [`sampled_table`] routine the sequential correlator uses, in
    /// canonical (a, b) orientation — and the tables are collected at
    /// wire size. Only the windows' slices of each reference column are
    /// priced into the broadcast, so a sketch over an already-built
    /// columnar layout ships `refs × sampled_rows` bytes. Counts are
    /// u64, so the tables (and any bounds derived from them) are
    /// bit-identical to the sequential and hp sketches.
    pub fn sampled_ctables(
        &self,
        pairs: &[(FeatureId, FeatureId)],
        windows: &[Range<usize>],
    ) -> Vec<ContingencyTable> {
        if pairs.is_empty() || windows.is_empty() {
            return vec![];
        }
        let (refs_bc, work) = self.batch_assembly(pairs, windows_len(windows));

        let data = Arc::clone(&self.data);
        let w2 = Arc::clone(&work);
        let class_bc = self.class_bc.clone();
        let windows = windows.to_vec();
        let tables: Rdd<(usize, ContingencyTable)> =
            self.columns.map_partitions("localCTablesSampled", move |_, cols| {
                let _ = &refs_bc; // broadcast lifetime mirrors Spark semantics
                let (class_col, class_arity) = (&class_bc.0, class_bc.1);
                let mut out = Vec::new();
                for (fid, col) in cols {
                    let Some(items) = w2.get(fid) else { continue };
                    for &(pair_idx, (a, b)) in items {
                        let class = (class_col.as_slice(), class_arity);
                        let (x, bins_x) = resolve_side(a, *fid, col, class, &data);
                        let (y, bins_y) = resolve_side(b, *fid, col, class, &data);
                        out.push((pair_idx, sampled_table(x, bins_x, y, bins_y, &windows)));
                    }
                }
                out
            });
        let mut collected = tables.collect_sized(|(_, t)| t.wire_bytes());
        collected.sort_by_key(|(i, _)| *i);
        debug_assert_eq!(collected.len(), pairs.len());
        collected.into_iter().map(|(_, t)| t).collect()
    }
}

/// Resolve one side of a pair to its column data inside a `localSU`
/// task: the class comes from its broadcast, the partition-owned column
/// (`fid`) from the partition itself, and any other (reference) column
/// from the dataset — one definition for both pair orientations, so the
/// resolution rules cannot drift apart.
fn resolve_side<'a>(
    id: FeatureId,
    fid: FeatureId,
    col: &'a [u8],
    class: (&'a [u8], u16),
    data: &'a DiscreteDataset,
) -> (&'a [u8], u16) {
    if id == CLASS_ID {
        class
    } else if id == fid {
        (col, data.arities[id])
    } else {
        data.column(id)
    }
}

/// Like hp, the vp batch job only reads shared state (the columnar RDD,
/// the class broadcast, the dataset), so one instance serves concurrent
/// searches. Note the reference-side choice depends on the *batch*
/// composition, but the SU value of every pair is computed in canonical
/// orientation regardless — coalescing batches across queries cannot
/// change any value.
impl SharedCorrelator for VerticalCorrelator {
    fn supports_ctables(&self) -> bool {
        true
    }

    /// The vp **table job** (DESIGN.md §12): like a correlation batch,
    /// but each owner partition builds its pairs' complete contingency
    /// tables over the row range `rows` and the tables are collected at
    /// their wire size (vp's one concession to incrementality — scalar
    /// batches never ship tables). Only the range's slice of each
    /// reference column is priced into the broadcast, which is what
    /// makes tall-and-tiny delta jobs cheap here.
    fn compute_ctables(
        &self,
        pairs: &[(FeatureId, FeatureId)],
        rows: Range<usize>,
    ) -> Vec<ContingencyTable> {
        if pairs.is_empty() {
            return vec![];
        }
        debug_assert!(rows.end <= self.data.num_rows());
        // Only the delta slice of each reference column ships.
        let (refs_bc, work) = self.batch_assembly(pairs, rows.len());

        let data = Arc::clone(&self.data);
        let w2 = Arc::clone(&work);
        let class_bc = self.class_bc.clone();
        let tables: Rdd<(usize, ContingencyTable)> =
            self.columns.map_partitions("localCTablesDelta", move |_, cols| {
                let _ = &refs_bc; // broadcast lifetime mirrors Spark semantics
                let (class_col, class_arity) = (&class_bc.0, class_bc.1);
                let mut out = Vec::new();
                for (fid, col) in cols {
                    let Some(items) = w2.get(fid) else { continue };
                    for &(pair_idx, (a, b)) in items {
                        let class = (class_col.as_slice(), class_arity);
                        let (x, bins_x) = resolve_side(a, *fid, col, class, &data);
                        let (y, bins_y) = resolve_side(b, *fid, col, class, &data);
                        out.push((
                            pair_idx,
                            ContingencyTable::from_columns_range(x, bins_x, y, bins_y, rows.clone()),
                        ));
                    }
                }
                out
            });
        let mut collected = tables.collect_sized(|(_, t)| t.wire_bytes());
        collected.sort_by_key(|(i, _)| *i);
        debug_assert_eq!(collected.len(), pairs.len());
        collected.into_iter().map(|(_, t)| t).collect()
    }

    fn compute_batch(&self, pairs: &[(FeatureId, FeatureId)]) -> Vec<f64> {
        if pairs.is_empty() {
            return vec![];
        }
        // Broadcast the non-class reference columns for this batch
        // (every column has `num_rows` rows, so the wire cost is
        // refs × n bytes) and group the pairs by owner column. The pair
        // stays in its canonical (a, b) orientation so the f64 SU value
        // is bit-identical to the sequential/hp computation —
        // transposing the table permutes the entropy summation order,
        // which can differ in the last ulp and flip merit ties.
        let (refs_bc, work) = self.batch_assembly(pairs, self.data.num_rows());

        // localSU: each partition computes SU for the pairs whose owner
        // column it holds, in one engine batch. Worker-side data paths:
        // the owner column comes from the partition itself (what the
        // columnar shuffle delivered), the class column from its
        // broadcast; only non-class *reference* columns are resolved from
        // the driver dataset (their transmission is priced by `refs_bc`).
        let data = Arc::clone(&self.data);
        let engine = Arc::clone(&self.engine);
        let w2 = Arc::clone(&work);
        let class_bc = self.class_bc.clone();
        let sus: Rdd<(usize, f64)> = self.columns.map_partitions("localSU", move |_, cols| {
            let _ = &refs_bc; // broadcast lifetime mirrors Spark semantics
            let (class_col, class_arity) = (&class_bc.0, class_bc.1);
            let mut idx: Vec<usize> = Vec::new();
            let mut batch: Vec<ColumnPair> = Vec::new();
            for (fid, col) in cols {
                let Some(items) = w2.get(fid) else { continue };
                for &(pair_idx, (a, b)) in items {
                    let class = (class_col.as_slice(), class_arity);
                    let (x, bins_x) = resolve_side(a, *fid, col, class, &data);
                    let (y, bins_y) = resolve_side(b, *fid, col, class, &data);
                    idx.push(pair_idx);
                    batch.push(ColumnPair {
                        x,
                        bins_x,
                        y,
                        bins_y,
                    });
                }
            }
            let values = engine.su_from_column_pairs(&batch);
            idx.into_iter().zip(values).collect()
        });

        // Shared job-assembly tail (plan.rs): collect 8 B scalars,
        // restore request order.
        plan::collect_su(&sus, pairs.len())
    }

    /// Sound SU intervals from the vp sampled-sketch job (DESIGN.md §16):
    /// run [`Self::sampled_ctables`] over the deterministic default
    /// windows, then finish into intervals on the driver with exact
    /// full-column marginals. Declines only when the dataset is too small
    /// to carry sample windows.
    fn compute_bounds_batch(&self, pairs: &[(FeatureId, FeatureId)]) -> Option<SuBounds> {
        if pairs.is_empty() {
            return Some(SuBounds::default());
        }
        let windows = default_windows(self.data.num_rows());
        if windows.is_empty() {
            return None;
        }
        let tables = self.sampled_ctables(pairs, &windows);
        Some(bounds_for_pairs(
            &self.data,
            &self.marginals,
            pairs,
            &tables,
            windows_len(&windows),
        ))
    }
}

impl Correlator for VerticalCorrelator {
    fn compute(&mut self, pairs: &[(FeatureId, FeatureId)]) -> Vec<f64> {
        self.compute_batch(pairs)
    }

    fn compute_bounds(&mut self, pairs: &[(FeatureId, FeatureId)]) -> Option<SuBounds> {
        self.compute_bounds_batch(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::correlation::su::symmetrical_uncertainty;
    use crate::data::synth::{epsilon_like, SynthConfig};
    use crate::discretize::discretize_dataset;
    use crate::runtime::NativeEngine;
    use crate::sparklet::ClusterConfig;

    fn setup(parts: usize) -> (Arc<SparkletContext>, VerticalCorrelator, Arc<DiscreteDataset>) {
        let ds = epsilon_like(&SynthConfig {
            rows: 600,
            seed: 55,
            features: Some(14),
        });
        let dd = Arc::new(discretize_dataset(&ds).unwrap());
        let ctx = SparkletContext::new(ClusterConfig::with_nodes(3));
        let corr = VerticalCorrelator::new(&ctx, Arc::clone(&dd), Arc::new(NativeEngine), parts);
        (ctx, corr, dd)
    }

    #[test]
    fn matches_direct_su_exactly() {
        let (_ctx, mut corr, dd) = setup(14);
        let pairs = vec![(0, CLASS_ID), (3, CLASS_ID), (0, 3), (5, 9), (13, 2)];
        let got = corr.compute(&pairs);
        for (i, &(a, b)) in pairs.iter().enumerate() {
            let (x, bx) = dd.column(a);
            let (y, by) = dd.column(b);
            assert_eq!(
                got[i],
                symmetrical_uncertainty(x, bx, y, by),
                "pair {:?}",
                (a, b)
            );
        }
    }

    #[test]
    fn partition_count_does_not_change_results() {
        let pairs = vec![(0, CLASS_ID), (1, 2), (3, CLASS_ID), (1, 7)];
        let (_c1, mut few, _) = setup(2);
        let (_c2, mut many, _) = setup(14);
        assert_eq!(few.compute(&pairs), many.compute(&pairs));
    }

    #[test]
    fn columnar_transformation_prices_whole_dataset() {
        let (ctx, _corr, dd) = setup(7);
        let m = ctx.metrics();
        let stage = m
            .stages
            .iter()
            .find(|s| s.label == "columnarTransformation")
            .expect("transformation stage");
        let data_bytes: usize = dd.cols.iter().map(Vec::len).sum();
        assert_eq!(stage.shuffle_bytes, data_bytes);
    }

    #[test]
    fn reference_side_prefers_class_and_shared_feature() {
        let sides = VerticalCorrelator::assign_sides(&[
            (4, CLASS_ID),
            (CLASS_ID, 7),
            (1, 9),
            (2, 9),
            (3, 9),
        ]);
        assert_eq!(sides[0], (4, CLASS_ID));
        assert_eq!(sides[1], (7, CLASS_ID));
        // 9 appears three times → it is the broadcast reference
        assert_eq!(sides[2], (1, 9));
        assert_eq!(sides[3], (2, 9));
        assert_eq!(sides[4], (3, 9));
    }

    #[test]
    fn broadcast_bytes_grow_with_reference_columns() {
        let (ctx, mut corr, dd) = setup(14);
        let before = ctx.metrics().total_broadcast_bytes();
        let _ = corr.compute(&[(0, 5), (1, 5), (2, 5)]);
        let after = ctx.metrics().total_broadcast_bytes();
        // one reference column (feature 5) of n rows was broadcast
        assert_eq!(after - before, dd.num_rows());
    }

    #[test]
    fn empty_batch() {
        let (_ctx, mut corr, _) = setup(3);
        assert!(corr.compute(&[]).is_empty());
    }

    #[test]
    fn ctable_job_matches_direct_tables_and_prices_delta_broadcast() {
        let (ctx, corr, dd) = setup(14);
        assert!(corr.supports_ctables());
        let n = dd.num_rows();
        let pairs = vec![(0, 5), (1, 5), (3, CLASS_ID)];

        // Full-range tables equal the driver-side computation exactly,
        // in the canonical (a, b) orientation.
        let full = corr.compute_ctables(&pairs, 0..n);
        for (t, &(a, b)) in full.iter().zip(&pairs) {
            let (x, bx) = dd.column(a);
            let (y, by) = dd.column(b);
            assert_eq!(t, &ContingencyTable::from_columns(x, bx, y, by));
        }

        // Base ⊕ delta == full, and the delta broadcast ships only the
        // delta slice of the reference column (feature 5).
        let split = n - 100;
        let base = corr.compute_ctables(&pairs, 0..split);
        let before = ctx.metrics().total_broadcast_bytes();
        let delta = corr.compute_ctables(&pairs, split..n);
        let after = ctx.metrics().total_broadcast_bytes();
        assert_eq!(after - before, 100, "delta slice of one reference column");
        for ((mut b, d), f) in base.into_iter().zip(delta).zip(&full) {
            b.merge(&d).unwrap();
            assert_eq!(&b, f);
        }
    }

    #[test]
    fn plan_predicts_the_job_it_lowers_to() {
        // The vp IR is honest: predicted broadcast/collect bytes are the
        // bytes the executed batch records, and there is no table
        // shuffle.
        let (ctx, corr, dd) = setup(14);
        let pairs = vec![(0, 5), (1, 5), (2, 5), (3, CLASS_ID)];
        let spec = corr.plan(&pairs);
        let before = ctx.metrics();
        let _ = corr.compute_batch(&pairs);
        let after = ctx.metrics();
        assert!(spec.shuffle.is_none());
        assert_eq!(spec.setup_shuffle_bytes, 0, "layout already built");
        // one reference column (feature 5) of n rows
        assert_eq!(spec.broadcast_bytes, dd.num_rows());
        assert_eq!(
            after.total_broadcast_bytes() - before.total_broadcast_bytes(),
            spec.broadcast_bytes
        );
        let collect = after.stages.last().unwrap();
        assert_eq!(collect.collect_bytes, spec.collect_bytes);
    }

    #[test]
    fn sampled_job_matches_sequential_sketch_and_prices_window_broadcast() {
        use crate::cfs::sequential::SequentialCorrelator;

        let (ctx, corr, dd) = setup(14);
        let pairs = vec![(0, 5), (1, 5), (3, CLASS_ID)];
        let windows = default_windows(dd.num_rows());
        assert!(!windows.is_empty());
        let sampled_rows = windows_len(&windows);

        // The sketch broadcast ships only the windows' slices of the one
        // non-class reference column (feature 5).
        let before = ctx.metrics().total_broadcast_bytes();
        let tables = corr.sampled_ctables(&pairs, &windows);
        let after = ctx.metrics().total_broadcast_bytes();
        // refs slice + the broadcast pair list is not part of this job
        // kind (vp ships the owner map through the closure), so the
        // delta is exactly one sliced reference column.
        assert_eq!(after - before, sampled_rows);

        // Owner-partition sampled tables equal the driver-side sampled
        // tables bit-for-bit, in canonical (a, b) orientation.
        for (t, &(a, b)) in tables.iter().zip(&pairs) {
            let (x, bx) = dd.column(a);
            let (y, by) = dd.column(b);
            assert_eq!(t, &sampled_table(x, bx, y, by, &windows));
        }

        // Scheme-independence: vp bounds == sequential bounds, bit-for-bit
        // — with hp.rs's matching test this pins seq == hp == vp.
        let vp = corr.compute_bounds_batch(&pairs).expect("600 rows sketch");
        let mut seq = SequentialCorrelator::new(&dd);
        let sq = seq.compute_bounds(&pairs).unwrap();
        assert_eq!(vp.sampled_cells, sq.sampled_cells);
        for (a, b) in vp.intervals.iter().zip(&sq.intervals) {
            assert_eq!(a, b);
        }

        // The exact SU sits inside every interval.
        for (iv, &(a, b)) in vp.intervals.iter().zip(&pairs) {
            let (x, bx) = dd.column(a);
            let (y, by) = dd.column(b);
            let exact = symmetrical_uncertainty(x, bx, y, by);
            assert!(iv.lo <= exact && exact <= iv.hi);
        }
    }

    #[test]
    fn correlator_is_shareable_across_threads() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<VerticalCorrelator>();

        let (_ctx, corr, dd) = setup(7);
        let (corr, dd) = (&corr, &dd);
        std::thread::scope(|s| {
            for offset in 0..3usize {
                s.spawn(move || {
                    let pairs = vec![(offset, CLASS_ID), (offset, offset + 4)];
                    let got = corr.compute_batch(&pairs);
                    for (i, &(a, b)) in pairs.iter().enumerate() {
                        let (x, bx) = dd.column(a);
                        let (y, by) = dd.column(b);
                        assert_eq!(got[i], symmetrical_uncertainty(x, bx, y, by));
                    }
                });
            }
        });
    }
}
