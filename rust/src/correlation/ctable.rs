//! Contingency tables — the paper's Algorithm 2 data structure.
//!
//! A table counts co-occurrences of two discretized features' bins. It is
//! the unit that workers compute locally and the driver merges by
//! element-wise sum (`reduceByKey(sum)` in Eq. 4). Counts are `u64`
//! (exact), so merges are associative/commutative and the distributed
//! result is bit-identical to the sequential one regardless of partition
//! order — the foundation of the hp ≡ vp ≡ sequential equivalence test.

use crate::core::{Error, Result};

/// Dense 2-D count table, row-major: `counts[x * bins_y + y]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ContingencyTable {
    /// Arity of the first (row) variable.
    pub bins_x: u16,
    /// Arity of the second (column) variable.
    pub bins_y: u16,
    /// Row-major counts, length `bins_x * bins_y`.
    pub counts: Vec<u64>,
}

impl ContingencyTable {
    /// Empty table of the given shape.
    pub fn new(bins_x: u16, bins_y: u16) -> Self {
        Self {
            bins_x,
            bins_y,
            counts: vec![0; bins_x as usize * bins_y as usize],
        }
    }

    /// Count one co-occurrence.
    #[inline]
    pub fn bump(&mut self, x: u8, y: u8) {
        debug_assert!(u16::from(x) < self.bins_x && u16::from(y) < self.bins_y);
        self.counts[x as usize * self.bins_y as usize + y as usize] += 1;
    }

    /// Build from two aligned columns — the sequential Algorithm 2.
    ///
    /// This is the L3 numeric hot loop (EXPERIMENTS.md §Perf): a dense
    /// scatter-count, shared with the incremental path via
    /// [`Self::merge_rows`]. Bin indices are validated against the arity
    /// by `DiscreteDataset::new`, so the unchecked indexing in
    /// `merge_rows` cannot go out of bounds for any dataset constructed
    /// through the public API; a debug assertion still guards test
    /// builds.
    pub fn from_columns(x: &[u8], bins_x: u16, y: &[u8], bins_y: u16) -> Self {
        debug_assert_eq!(x.len(), y.len());
        let mut t = Self::new(bins_x, bins_y);
        // One scatter-count definition for the whole crate: building
        // from scratch is delta-merging into an empty table.
        t.merge_rows(x, y, 0..x.len());
        t
    }

    /// Build from a row range of two columns (one partition's share).
    ///
    /// Feeds the range straight into the [`Self::merge_rows`] scatter
    /// loop — one slice resolution per column, no intermediate re-sliced
    /// borrows (this used to go through [`Self::from_columns`] on
    /// pre-sliced columns, paying the slicing twice per call on the
    /// scalar fallback path).
    pub fn from_columns_range(
        x: &[u8],
        bins_x: u16,
        y: &[u8],
        bins_y: u16,
        range: std::ops::Range<usize>,
    ) -> Self {
        let mut t = Self::new(bins_x, bins_y);
        t.merge_rows(x, y, range);
        t
    }

    /// Delta-merge: scatter-count the row range `rows` of two columns
    /// directly into this table — the incremental-append primitive.
    ///
    /// Because counts are exact `u64` sums, a table built over `0..n`
    /// rows and then delta-merged with `n..n2` is **bit-identical** to a
    /// table built from scratch over `0..n2` (asserted by
    /// `delta_merge_equals_from_scratch` below). This is what lets the
    /// versioned SU cache (`cache::VersionedMeasureCache`) upgrade cached
    /// tables after a dataset append by scanning only the delta rows,
    /// and what makes [`Self::marginals`] of an upgraded table equal the
    /// marginals of the from-scratch one (marginals are sums of counts,
    /// so they inherit additivity).
    pub fn merge_rows(&mut self, x: &[u8], y: &[u8], rows: std::ops::Range<usize>) {
        debug_assert_eq!(x.len(), y.len());
        let by = self.bins_y as usize;
        let counts = &mut self.counts[..];
        for (&xv, &yv) in x[rows.clone()].iter().zip(&y[rows]) {
            let idx = xv as usize * by + yv as usize;
            debug_assert!(idx < counts.len());
            // SAFETY: same invariant as `from_columns` — bin indices are
            // validated against the arity at dataset construction.
            unsafe { *counts.get_unchecked_mut(idx) += 1 };
        }
    }

    /// Element-wise merge (the `reduceByKey` combiner). Errors on shape
    /// mismatch — merging tables of different pairs is a coordinator bug.
    pub fn merge(&mut self, other: &ContingencyTable) -> Result<()> {
        if self.bins_x != other.bins_x || self.bins_y != other.bins_y {
            return Err(Error::InvalidData(format!(
                "merge shape mismatch: {}x{} vs {}x{}",
                self.bins_x, self.bins_y, other.bins_x, other.bins_y
            )));
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        Ok(())
    }

    /// Total count (number of contributing instances).
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// All marginals in a single scan of `counts`:
    /// `(total, row_marginals, col_marginals)`.
    ///
    /// [`Self::total`], [`Self::row_marginals`] and
    /// [`Self::col_marginals`] each rescan the table; the SU/entropy hot
    /// path needs all three, so it uses this fused accumulation instead
    /// (one pass over the cells, exact u64 sums — results are
    /// bit-identical to the three separate scans).
    pub fn marginals(&self) -> (u64, Vec<u64>, Vec<u64>) {
        let bx = self.bins_x as usize;
        let by = self.bins_y as usize;
        let mut rows = vec![0u64; bx];
        let mut cols = vec![0u64; by];
        let mut total = 0u64;
        for (x, row) in self.counts.chunks_exact(by.max(1)).take(bx).enumerate() {
            let mut r = 0u64;
            for (c, m) in row.iter().zip(cols.iter_mut()) {
                r += c;
                *m += c;
            }
            rows[x] = r;
            total += r;
        }
        (total, rows, cols)
    }

    /// Row marginals (counts of the first variable).
    pub fn row_marginals(&self) -> Vec<u64> {
        let by = self.bins_y as usize;
        (0..self.bins_x as usize)
            .map(|x| self.counts[x * by..(x + 1) * by].iter().sum())
            .collect()
    }

    /// Column marginals (counts of the second variable).
    pub fn col_marginals(&self) -> Vec<u64> {
        let by = self.bins_y as usize;
        let mut m = vec![0u64; by];
        for x in 0..self.bins_x as usize {
            for y in 0..by {
                m[y] += self.counts[x * by + y];
            }
        }
        m
    }

    /// Transposed table (SU symmetry tests).
    pub fn transposed(&self) -> Self {
        let mut t = Self::new(self.bins_y, self.bins_x);
        let by = self.bins_y as usize;
        let bx = self.bins_x as usize;
        for x in 0..bx {
            for y in 0..by {
                t.counts[y * bx + x] = self.counts[x * by + y];
            }
        }
        t
    }

    /// Serialized size in bytes when shipped through a (simulated) shuffle:
    /// shape header + one u64 per cell. The sparklet cost model charges
    /// this amount per table per network hop.
    pub fn wire_bytes(&self) -> usize {
        Self::wire_bytes_for_cells(self.counts.len())
    }

    /// [`Self::wire_bytes`] for a table of `cells` counts, without
    /// building it — the partitioning planner prices hp shuffles from
    /// arities alone, and must agree byte-for-byte with what an executed
    /// job records.
    pub const fn wire_bytes_for_cells(cells: usize) -> usize {
        4 + cells * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_columns_counts_correctly() {
        let x = [0u8, 0, 1, 1, 1];
        let y = [0u8, 1, 0, 1, 1];
        let t = ContingencyTable::from_columns(&x, 2, &y, 2);
        assert_eq!(t.counts, vec![1, 1, 1, 2]);
        assert_eq!(t.total(), 5);
    }

    #[test]
    fn marginals() {
        let t = ContingencyTable::from_columns(&[0, 0, 1, 2], 3, &[1, 0, 1, 1], 2);
        assert_eq!(t.row_marginals(), vec![2, 1, 1]);
        assert_eq!(t.col_marginals(), vec![1, 3]);
    }

    #[test]
    fn fused_marginals_match_separate_scans() {
        let t = ContingencyTable::from_columns(
            &[0, 0, 1, 2, 2, 1, 0, 2],
            3,
            &[1, 0, 1, 1, 0, 0, 1, 1],
            2,
        );
        let (total, rows, cols) = t.marginals();
        assert_eq!(total, t.total());
        assert_eq!(rows, t.row_marginals());
        assert_eq!(cols, t.col_marginals());

        // Empty table: zero total, zeroed marginals of the right shapes.
        let e = ContingencyTable::new(4, 3);
        let (total, rows, cols) = e.marginals();
        assert_eq!(total, 0);
        assert_eq!(rows, vec![0; 4]);
        assert_eq!(cols, vec![0; 3]);
    }

    #[test]
    fn merge_equals_whole() {
        // Partition-wise tables merged == whole-column table: the exact
        // property Eq. 4 relies on.
        let x = [0u8, 1, 0, 1, 1, 0, 0, 1];
        let y = [1u8, 1, 0, 0, 1, 1, 0, 0];
        let whole = ContingencyTable::from_columns(&x, 2, &y, 2);
        let mut merged = ContingencyTable::from_columns_range(&x, 2, &y, 2, 0..3);
        merged
            .merge(&ContingencyTable::from_columns_range(&x, 2, &y, 2, 3..8))
            .unwrap();
        assert_eq!(whole, merged);
    }

    #[test]
    fn delta_merge_equals_from_scratch() {
        // The incremental invariant: table(0..n) ⊕ rows(n..n2) is
        // bit-identical to table(0..n2), and so are its marginals.
        let x = [0u8, 1, 2, 0, 1, 2, 2, 1, 0, 2];
        let y = [1u8, 0, 1, 1, 1, 0, 0, 1, 0, 1];
        let whole = ContingencyTable::from_columns(&x, 3, &y, 2);
        let mut upgraded = ContingencyTable::from_columns_range(&x, 3, &y, 2, 0..6);
        upgraded.merge_rows(&x, &y, 6..10);
        assert_eq!(whole, upgraded);
        assert_eq!(whole.marginals(), upgraded.marginals());
        // Delta-merging in several steps is equally exact.
        let mut stepped = ContingencyTable::from_columns_range(&x, 3, &y, 2, 0..3);
        stepped.merge_rows(&x, &y, 3..7);
        stepped.merge_rows(&x, &y, 7..10);
        assert_eq!(whole, stepped);
        // An empty delta is a no-op.
        stepped.merge_rows(&x, &y, 5..5);
        assert_eq!(whole, stepped);
    }

    #[test]
    fn range_construction_matches_slice_then_scan() {
        // Regression pin for the `from_columns_range` fast path: the
        // direct range scatter must count exactly what the old
        // slice-first formulation (`from_columns(&x[r], ..)`) counted,
        // across randomized shapes, arities and (possibly empty) ranges.
        let mut rng = crate::util::XorShift64Star::new(0xC7AB1E);
        for _ in 0..200 {
            let n = rng.next_below(400) as usize + 1;
            let bins_x = rng.next_below(12) as u16 + 1;
            let bins_y = rng.next_below(12) as u16 + 1;
            let x: Vec<u8> = (0..n).map(|_| rng.next_below(bins_x as u64) as u8).collect();
            let y: Vec<u8> = (0..n).map(|_| rng.next_below(bins_y as u64) as u8).collect();
            let a = rng.next_below(n as u64 + 1) as usize;
            let b = rng.next_below(n as u64 + 1) as usize;
            let range = a.min(b)..a.max(b);
            let fast = ContingencyTable::from_columns_range(&x, bins_x, &y, bins_y, range.clone());
            let old = ContingencyTable::from_columns(&x[range.clone()], bins_x, &y[range], bins_y);
            assert_eq!(fast, old);
        }
    }

    #[test]
    fn merge_rejects_shape_mismatch() {
        let mut a = ContingencyTable::new(2, 2);
        let b = ContingencyTable::new(2, 3);
        assert!(a.merge(&b).is_err());
    }

    #[test]
    fn transpose_swaps_marginals() {
        let t = ContingencyTable::from_columns(&[0, 0, 1, 2], 3, &[1, 0, 1, 1], 2);
        let tt = t.transposed();
        assert_eq!(tt.row_marginals(), t.col_marginals());
        assert_eq!(tt.col_marginals(), t.row_marginals());
        assert_eq!(tt.total(), t.total());
    }

    #[test]
    fn wire_bytes_tracks_shape() {
        assert_eq!(ContingencyTable::new(2, 2).wire_bytes(), 4 + 4 * 8);
        assert_eq!(ContingencyTable::new(32, 32).wire_bytes(), 4 + 1024 * 8);
        // The cell-count form (used by the planner) agrees by definition.
        assert_eq!(
            ContingencyTable::wire_bytes_for_cells(4),
            ContingencyTable::new(2, 2).wire_bytes()
        );
    }
}
