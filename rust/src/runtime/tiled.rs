//! Cache-blocked tiled SU engine: one flat count slab for a whole pair
//! batch, filled tile by tile.
//!
//! [`NativeEngine`](crate::runtime::NativeEngine) processes a batch one
//! pair at a time: allocate that pair's `ContingencyTable`, stream every
//! row through it, move on. [`TiledEngine`] restructures the same work
//! around fixed `(P, N, B)` tiles:
//!
//! * **P** — pairs per tile. Up to `P` pairs share one flat `u64` count
//!   slab (`P × B` cells, one `B`-strided stripe per pair), allocated
//!   once per tile and reused across row tiles — no per-pair allocation
//!   in the hot loop.
//! * **N** — rows per tile. The row range is walked in `N`-row chunks,
//!   and *all* `P` pairs consume a chunk before the walk advances. CFS
//!   batches share columns heavily (feature-vs-class pairs all read the
//!   class column), so the shared column's tile stays cache-resident
//!   across the `P` scans instead of being re-streamed from memory per
//!   pair, and the slab itself (at the default shape, 64 KiB) never
//!   leaves L1/L2.
//! * **B** — the cell budget (`bins_x × bins_y`) of a slab stripe. Pairs
//!   whose table exceeds `B` cells take the scalar
//!   [`ContingencyTable::from_columns_range`] fallback; everything else
//!   goes through the slab.
//!
//! The inner loop is branch-light and bounds-check-free (the same
//! validated-bins invariant `ContingencyTable::merge_rows` relies on),
//! and interleaves **two pair stripes per pass** so the scatter-increment
//! dependence chains of independent histograms overlap — the classic
//! multi-histogram trick, here across pairs instead of sub-histograms.
//!
//! **Exactness.** The slab holds `u64` counts bumped by 1 per row — the
//! identical additions `merge_rows` performs, in a different order, and
//! integer addition is commutative. The finish assembles each stripe
//! back into a `ContingencyTable` of the pair's true shape and runs the
//! very same [`su_from_table`] the native engine runs. Every result is
//! therefore **bit-identical** to `NativeEngine`'s, which the engine
//! axis of `tests/proptests.rs` asserts across shapes, ragged batches
//! and arities straddling `B`.

use crate::correlation::su::su_from_table;
use crate::correlation::ContingencyTable;
use crate::runtime::{ColumnPair, SuEngine};

/// Default pairs per tile (`P`).
pub const TILE_PAIRS: usize = 8;
/// Default rows per tile (`N`).
pub const TILE_ROWS: usize = 4096;
/// Default cell budget per pair stripe (`B`), in table cells.
pub const TILE_BINS: usize = 1024;

/// One pair's view of the current row tile: its slab stripe base and the
/// column slices cut to the tile.
struct Slot<'a> {
    base: usize,
    by: usize,
    x: &'a [u8],
    y: &'a [u8],
}

/// Scatter-count one row tile into a single pair's slab stripe.
#[inline]
fn bump_one(counts: &mut [u64], s: &Slot<'_>) {
    for (&xv, &yv) in s.x.iter().zip(s.y) {
        let idx = s.base + xv as usize * s.by + yv as usize;
        debug_assert!(idx < counts.len());
        // SAFETY: bin indices are validated against the arity at dataset
        // construction (the `merge_rows` invariant), so
        // `xv * by + yv < bins_x * bins_y ≤ B` and the index stays inside
        // this pair's stripe.
        unsafe { *counts.get_unchecked_mut(idx) += 1 };
    }
}

/// Scatter-count one row tile for two pair stripes in a single pass.
/// The two increment chains are independent (disjoint stripes), so the
/// store-to-load dependences of repeated cells overlap instead of
/// serializing — the measurable win over the one-pair-at-a-time loop.
#[inline]
fn bump_two(counts: &mut [u64], a: &Slot<'_>, b: &Slot<'_>) {
    debug_assert_eq!(a.x.len(), a.y.len());
    debug_assert_eq!(a.x.len(), b.x.len());
    debug_assert_eq!(b.x.len(), b.y.len());
    let n = a.x.len();
    for i in 0..n {
        // SAFETY: all four slices are cut from the same row tile, so
        // `i < n` is in bounds for each; slab indices stay inside their
        // stripes by the same validated-bins invariant as `bump_one`.
        unsafe {
            let ia =
                a.base + *a.x.get_unchecked(i) as usize * a.by + *a.y.get_unchecked(i) as usize;
            let ib =
                b.base + *b.x.get_unchecked(i) as usize * b.by + *b.y.get_unchecked(i) as usize;
            debug_assert!(ia < counts.len() && ib < counts.len());
            *counts.get_unchecked_mut(ia) += 1;
            *counts.get_unchecked_mut(ib) += 1;
        }
    }
}

/// Cache-blocked batch engine. Bit-identical to
/// [`NativeEngine`](crate::runtime::NativeEngine) (see the module doc's
/// exactness argument); faster on wide pair batches.
#[derive(Debug, Clone, Copy)]
pub struct TiledEngine {
    tile_pairs: usize,
    tile_rows: usize,
    tile_bins: usize,
}

impl Default for TiledEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl TiledEngine {
    /// Engine with the default `(P, N, B)` tile shape.
    pub fn new() -> Self {
        Self::with_tiles(TILE_PAIRS, TILE_ROWS, TILE_BINS)
    }

    /// Engine with an explicit tile shape — tests use tiny tiles to
    /// exercise ragged boundaries and the `B` fallback. All dimensions
    /// must be at least 1.
    pub fn with_tiles(tile_pairs: usize, tile_rows: usize, tile_bins: usize) -> Self {
        assert!(
            tile_pairs >= 1 && tile_rows >= 1 && tile_bins >= 1,
            "tile dimensions must be positive"
        );
        Self {
            tile_pairs,
            tile_rows,
            tile_bins,
        }
    }

    /// Cells a pair's table needs; `None` means it exceeds the stripe
    /// budget `B` and takes the scalar fallback.
    fn stripe_cells(&self, p: &ColumnPair<'_>) -> Option<usize> {
        let cells = p.bins_x as usize * p.bins_y as usize;
        (cells <= self.tile_bins).then_some(cells)
    }
}

impl SuEngine for TiledEngine {
    fn name(&self) -> &'static str {
        "tiled"
    }

    fn ctables(
        &self,
        pairs: &[ColumnPair<'_>],
        rows: std::ops::Range<usize>,
    ) -> Vec<ContingencyTable> {
        let mut out: Vec<Option<ContingencyTable>> = vec![None; pairs.len()];
        // Split the batch: stripe-eligible pairs go through the slab,
        // oversize arities (> B cells) through the scalar path.
        let mut tiled: Vec<usize> = Vec::with_capacity(pairs.len());
        for (i, p) in pairs.iter().enumerate() {
            if self.stripe_cells(p).is_some() {
                tiled.push(i);
            } else {
                out[i] = Some(ContingencyTable::from_columns_range(
                    p.x,
                    p.bins_x,
                    p.y,
                    p.bins_y,
                    rows.clone(),
                ));
            }
        }

        // One slab, reused (re-zeroed) per P-tile of pairs.
        let mut slab: Vec<u64> = vec![0; self.tile_pairs.min(tiled.len()) * self.tile_bins];
        for chunk in tiled.chunks(self.tile_pairs) {
            let live = &mut slab[..chunk.len() * self.tile_bins];
            live.fill(0);

            // Walk the row range in N-tiles; every pair in the chunk
            // consumes a tile before the walk advances, keeping shared
            // column tiles and the slab cache-resident.
            let mut start = rows.start;
            while start < rows.end {
                let end = (start + self.tile_rows).min(rows.end);
                let slot = |k: usize| {
                    let p = &pairs[chunk[k]];
                    Slot {
                        base: k * self.tile_bins,
                        by: p.bins_y as usize,
                        x: &p.x[start..end],
                        y: &p.y[start..end],
                    }
                };
                let mut k = 0;
                while k + 1 < chunk.len() {
                    bump_two(live, &slot(k), &slot(k + 1));
                    k += 2;
                }
                if k < chunk.len() {
                    bump_one(live, &slot(k));
                }
                start = end;
            }

            // Assemble each stripe back into the pair's true shape. The
            // stripe prefix holds exactly the row-major counts a
            // `ContingencyTable` stores.
            for (k, &i) in chunk.iter().enumerate() {
                let p = &pairs[i];
                let cells = self.stripe_cells(p).expect("chunk holds eligible pairs");
                let mut t = ContingencyTable::new(p.bins_x, p.bins_y);
                t.counts
                    .copy_from_slice(&live[k * self.tile_bins..k * self.tile_bins + cells]);
                out[i] = Some(t);
            }
        }

        out.into_iter()
            .map(|t| t.expect("every pair assembled"))
            .collect()
    }

    fn su_from_tables(&self, tables: &[&ContingencyTable]) -> Vec<f64> {
        // The identical finish the native engine runs — bit-identity of
        // the SU values follows from bit-identity of the tables.
        tables.iter().map(|&t| su_from_table(t)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeEngine;
    use crate::util::XorShift64Star;

    fn random_cols(seed: u64, n: usize, bins: u16) -> Vec<u8> {
        let mut rng = XorShift64Star::new(seed);
        (0..n).map(|_| rng.next_below(bins as u64) as u8).collect()
    }

    /// A batch of pairs with mixed arities over shared columns (the CFS
    /// shape: many pairs read the same "class" column).
    fn batch(n: usize) -> (Vec<(Vec<u8>, u16)>, Vec<(usize, usize)>) {
        let arities: Vec<u16> = vec![2, 5, 8, 3, 16, 7, 4, 33];
        let cols: Vec<(Vec<u8>, u16)> = arities
            .iter()
            .enumerate()
            .map(|(i, &b)| (random_cols(100 + i as u64, n, b), b))
            .collect();
        // Every column vs column 0, plus a few cross pairs: 11 pairs —
        // ragged against the default and the tiny tile_pairs alike.
        let mut idx: Vec<(usize, usize)> = (1..cols.len()).map(|i| (i, 0)).collect();
        idx.extend([(2, 4), (7, 7), (5, 3), (0, 0)]);
        (cols, idx)
    }

    fn pairs_of<'a>(cols: &'a [(Vec<u8>, u16)], idx: &[(usize, usize)]) -> Vec<ColumnPair<'a>> {
        idx.iter()
            .map(|&(a, b)| ColumnPair {
                x: &cols[a].0,
                bins_x: cols[a].1,
                y: &cols[b].0,
                bins_y: cols[b].1,
            })
            .collect()
    }

    #[test]
    fn tables_match_native_across_tile_shapes() {
        let (cols, idx) = batch(1000);
        let pairs = pairs_of(&cols, &idx);
        let native = NativeEngine.ctables(&pairs, 0..1000);
        // Tile shapes chosen to hit every boundary: P dividing and not
        // dividing the batch, N dividing and not dividing the rows, B
        // forcing some / all pairs onto the scalar fallback.
        for (p, n, b) in [
            (TILE_PAIRS, TILE_ROWS, TILE_BINS),
            (1, 1, 1),          // everything degenerate: all-fallback, 1-row tiles
            (2, 7, 64),         // ragged everywhere; arity 16×33 falls back
            (3, 1000, 4096),    // single row tile, odd chunk size
            (11, 999, 16 * 33), // exact batch width, ragged rows, all eligible
        ] {
            let tiled = TiledEngine::with_tiles(p, n, b).ctables(&pairs, 0..1000);
            assert_eq!(tiled, native, "tile shape ({p},{n},{b}) diverged");
        }
    }

    #[test]
    fn row_subranges_match_native_and_merge_exactly() {
        let (cols, idx) = batch(500);
        let pairs = pairs_of(&cols, &idx);
        let e = TiledEngine::with_tiles(4, 64, 2048);
        let native = NativeEngine;
        for range in [0..500, 0..0, 17..17, 3..130, 130..500, 499..500] {
            assert_eq!(
                e.ctables(&pairs, range.clone()),
                native.ctables(&pairs, range.clone()),
                "range {range:?} diverged"
            );
        }
        // Disjoint subranges merge to the whole — the hp partition
        // invariant, through the tiled path.
        let whole = e.ctables(&pairs, 0..500);
        let mut low = e.ctables(&pairs, 0..201);
        let high = e.ctables(&pairs, 201..500);
        for (l, h) in low.iter_mut().zip(&high) {
            l.merge(h).unwrap();
        }
        assert_eq!(low, whole);
    }

    #[test]
    fn su_bit_identical_to_native() {
        let (cols, idx) = batch(800);
        let pairs = pairs_of(&cols, &idx);
        let tiled = TiledEngine::new().su_from_column_pairs(&pairs);
        let native = NativeEngine.su_from_column_pairs(&pairs);
        assert_eq!(tiled.len(), native.len());
        for (t, n) in tiled.iter().zip(&native) {
            assert_eq!(t.to_bits(), n.to_bits());
        }
    }

    #[test]
    fn arities_straddling_the_bin_budget() {
        // B = 100: the 8×12 pair (96 cells) squeaks under, the 9×12
        // (108) and 16×33 pairs fall back — both paths in one batch,
        // both bit-identical to native.
        let a = random_cols(1, 300, 8);
        let b = random_cols(2, 300, 12);
        let c = random_cols(3, 300, 9);
        let d = random_cols(4, 300, 16);
        let e = random_cols(5, 300, 33);
        let pairs = [
            ColumnPair {
                x: &a,
                bins_x: 8,
                y: &b,
                bins_y: 12,
            },
            ColumnPair {
                x: &c,
                bins_x: 9,
                y: &b,
                bins_y: 12,
            },
            ColumnPair {
                x: &d,
                bins_x: 16,
                y: &e,
                bins_y: 33,
            },
        ];
        let engine = TiledEngine::with_tiles(4, 128, 100);
        assert_eq!(
            engine.ctables(&pairs, 0..300),
            NativeEngine.ctables(&pairs, 0..300)
        );
        let tiled = engine.su_from_column_pairs(&pairs);
        let native = NativeEngine.su_from_column_pairs(&pairs);
        for (t, n) in tiled.iter().zip(&native) {
            assert_eq!(t.to_bits(), n.to_bits());
        }
    }

    #[test]
    fn empty_inputs() {
        let e = TiledEngine::new();
        assert!(e.ctables(&[], 0..0).is_empty());
        assert!(e.su_from_column_pairs(&[]).is_empty());
        assert!(e.su_from_tables(&[]).is_empty());
    }

    #[test]
    #[should_panic(expected = "tile dimensions must be positive")]
    fn zero_tile_dims_rejected() {
        let _ = TiledEngine::with_tiles(0, 1, 1);
    }
}
