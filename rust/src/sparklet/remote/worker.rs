//! The worker-process side of the protocol: what runs when the `dicfs`
//! binary is re-invoked as `dicfs --worker <socket>`.
//!
//! A worker connects to the driver's Unix socket, sends
//! [`WorkerMsg::Ready`], and then serves [`DriverMsg`]s until shutdown
//! or EOF. It holds exactly one installed dataset at a time and runs
//! each task through the engine named on its Task frame
//! ([`EngineKind`]) — the same kernels the in-process executors run,
//! which is the bit-identity guarantee (native and tiled produce
//! identical tables and SU values, so the driver's engine choice is
//! invisible in the results).
//!
//! The serve loop is separated from process plumbing so library tests
//! can drive a "worker" over a `UnixStream::pair()` without spawning a
//! process; the crash-injection path (`ArmCrash` → `process::exit`) is
//! only reachable in a real worker process and is exercised by the
//! integration tests.

use std::io;
use std::os::unix::net::UnixStream;
use std::time::Instant;

use crate::data::columnar::DiscreteDataset;
use crate::runtime::{NativeEngine, SuEngine, TiledEngine};

use super::protocol::{recv_msg, send_msg, DriverMsg, EngineKind, WorkerMsg};
use super::tasks::execute_task;

/// Exit code of a deliberately crashed worker (failure injection).
pub const CRASH_EXIT_CODE: i32 = 17;

/// Entry point for `--worker` mode: connect to the driver and serve
/// until shutdown. Returns the process exit code.
pub fn worker_main(socket_path: &str) -> i32 {
    let stream = match UnixStream::connect(socket_path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("dicfs worker: cannot connect to {socket_path}: {e}");
            return 1;
        }
    };
    match serve(stream, &mut RealCrash) {
        Ok(()) => 0,
        Err(e) => {
            // A vanished driver (EOF / broken pipe) is a normal way for
            // a worker to end; anything else is reported.
            if e.kind() == io::ErrorKind::UnexpectedEof || e.kind() == io::ErrorKind::BrokenPipe {
                0
            } else {
                eprintln!("dicfs worker: {e}");
                1
            }
        }
    }
}

/// How an armed crash fires. Abstracted so the serve loop is testable
/// in-process (a test hook records the trigger instead of exiting).
pub(crate) trait CrashHook {
    fn fire(&mut self) -> io::Result<()>;
}

struct RealCrash;

impl CrashHook for RealCrash {
    fn fire(&mut self) -> io::Result<()> {
        // Exit without replying: the driver observes a dead connection
        // with the task still in flight — a mid-shuffle worker loss.
        std::process::exit(CRASH_EXIT_CODE);
    }
}

/// Serve one driver connection to completion.
pub(crate) fn serve(mut stream: UnixStream, crash: &mut dyn CrashHook) -> io::Result<()> {
    send_msg(&mut stream, &WorkerMsg::Ready)?;
    // Both worker-side engines exist up front; each task picks one by
    // its frame's EngineKind. They are stateless and bit-identical.
    let native = NativeEngine;
    let tiled = TiledEngine::new();
    let mut data: Option<DiscreteDataset> = None;
    // `None` = disarmed; `Some(k)` = complete k more tasks normally,
    // then die on the next one.
    let mut crash_after: Option<u64> = None;

    loop {
        let (msg, _bytes): (DriverMsg, usize) = recv_msg(&mut stream)?;
        match msg {
            DriverMsg::Install(payload) => {
                data = Some(payload.into_dataset()?);
                send_msg(&mut stream, &WorkerMsg::Ready)?;
            }
            DriverMsg::Task { id, engine, task } => {
                if crash_after == Some(0) {
                    crash.fire()?;
                    // Test hook only: a real crash never returns.
                    continue;
                }
                let d = data
                    .as_ref()
                    .ok_or_else(|| super::codec::bad("task before dataset install"))?;
                let engine: &dyn SuEngine = match engine {
                    EngineKind::Native => &native,
                    EngineKind::Tiled => &tiled,
                };
                let t0 = Instant::now();
                let result = execute_task(d, engine, &task);
                let secs = t0.elapsed().as_secs_f64();
                send_msg(&mut stream, &WorkerMsg::Done { id, secs, result })?;
                if let Some(left) = crash_after.as_mut() {
                    *left = left.saturating_sub(1);
                }
            }
            DriverMsg::ArmCrash { after } => crash_after = Some(after),
            DriverMsg::Shutdown => return Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::CLASS_ID;
    use crate::correlation::ContingencyTable;
    use crate::sparklet::remote::protocol::{DatasetPayload, EngineKind, RemoteTask, TaskResult};

    struct RecordingCrash(bool);
    impl CrashHook for RecordingCrash {
        fn fire(&mut self) -> io::Result<()> {
            self.0 = true;
            // Simulate the vanishing worker by erroring out of serve.
            Err(io::Error::other("crashed"))
        }
    }

    fn dataset() -> DiscreteDataset {
        DiscreteDataset::new(
            "w",
            vec![vec![0, 1, 0, 1], vec![1, 1, 0, 0]],
            vec![2, 2],
            vec![0, 1, 0, 1],
            2,
        )
        .unwrap()
    }

    /// Drive `serve` over a socketpair from the test thread.
    fn with_worker(f: impl FnOnce(&mut UnixStream)) -> io::Result<()> {
        let (mut driver, worker) = UnixStream::pair().unwrap();
        let handle = std::thread::spawn(move || serve(worker, &mut RealCrashNever));
        let (ready, _): (WorkerMsg, usize) = recv_msg(&mut driver).unwrap();
        assert_eq!(ready, WorkerMsg::Ready);
        f(&mut driver);
        drop(driver); // EOF ends the serve loop
        handle.join().unwrap()
    }

    struct RealCrashNever;
    impl CrashHook for RealCrashNever {
        fn fire(&mut self) -> io::Result<()> {
            panic!("crash fired in a test that never armed one")
        }
    }

    #[test]
    fn install_then_task_over_socketpair() {
        let data = dataset();
        let expected = {
            let (x, bx) = data.column(0);
            let (y, by) = data.column(CLASS_ID);
            ContingencyTable::from_columns(x, bx, y, by)
        };
        let err = with_worker(|driver| {
            let install = DriverMsg::Install(DatasetPayload::from_dataset(&dataset()));
            send_msg(driver, &install).unwrap();
            let (ack, _): (WorkerMsg, usize) = recv_msg(driver).unwrap();
            assert_eq!(ack, WorkerMsg::Ready);

            // The same count task through each engine kind: identical
            // tables either way (the worker-side bit-identity check).
            for (id, engine) in [(42u64, EngineKind::Native), (43, EngineKind::Tiled)] {
                send_msg(
                    driver,
                    &DriverMsg::Task {
                        id,
                        engine,
                        task: RemoteTask::HpCount {
                            pairs: vec![(0, (0, CLASS_ID as u64))],
                            rows: 0..4,
                        },
                    },
                )
                .unwrap();
                let (reply, _): (WorkerMsg, usize) = recv_msg(driver).unwrap();
                let WorkerMsg::Done { id: got, secs, result } = reply else {
                    panic!("expected Done")
                };
                assert_eq!(got, id);
                assert!(secs >= 0.0);
                assert_eq!(result, TaskResult::Tables(vec![(0, expected.clone())]));
            }
        });
        // Driver hang-up is a clean end.
        assert!(err.is_err());
    }

    #[test]
    fn task_before_install_is_an_error() {
        let (mut driver, worker) = UnixStream::pair().unwrap();
        let handle = std::thread::spawn(move || serve(worker, &mut RealCrashNever));
        let (_ready, _): (WorkerMsg, usize) = recv_msg(&mut driver).unwrap();
        send_msg(
            &mut driver,
            &DriverMsg::Task {
                id: 1,
                engine: EngineKind::Native,
                task: RemoteTask::VpSu { pairs: vec![] },
            },
        )
        .unwrap();
        let res = handle.join().unwrap();
        assert!(res.is_err());
    }

    #[test]
    fn shutdown_ends_serve_cleanly() {
        let (mut driver, worker) = UnixStream::pair().unwrap();
        let handle = std::thread::spawn(move || serve(worker, &mut RealCrashNever));
        let (_ready, _): (WorkerMsg, usize) = recv_msg(&mut driver).unwrap();
        send_msg(&mut driver, &DriverMsg::Shutdown).unwrap();
        assert!(handle.join().unwrap().is_ok());
    }

    #[test]
    fn armed_crash_fires_after_count() {
        let (mut driver, worker) = UnixStream::pair().unwrap();
        let handle = std::thread::spawn(move || {
            let mut hook = RecordingCrash(false);
            let res = serve(worker, &mut hook);
            (res, hook.0)
        });
        let (_ready, _): (WorkerMsg, usize) = recv_msg(&mut driver).unwrap();
        send_msg(&mut driver, &DriverMsg::Install(DatasetPayload::from_dataset(&dataset())))
            .unwrap();
        let (_ack, _): (WorkerMsg, usize) = recv_msg(&mut driver).unwrap();
        // Arm: one more normal completion, then die.
        send_msg(&mut driver, &DriverMsg::ArmCrash { after: 1 }).unwrap();
        let task = |id| DriverMsg::Task {
            id,
            engine: EngineKind::Native,
            task: RemoteTask::VpSu {
                pairs: vec![(0, (0, 1))],
            },
        };
        send_msg(&mut driver, &task(1)).unwrap();
        let (first, _): (WorkerMsg, usize) = recv_msg(&mut driver).unwrap();
        assert!(matches!(first, WorkerMsg::Done { id: 1, .. }));
        // The next task triggers the armed crash: no reply, serve errors.
        send_msg(&mut driver, &task(2)).unwrap();
        let (res, fired) = handle.join().unwrap();
        assert!(res.is_err());
        assert!(fired, "crash hook never fired");
    }
}
