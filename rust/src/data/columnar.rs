//! Column-major dataset storage.
//!
//! Two representations:
//! * [`Dataset`] — raw mixed-type data as generated/loaded (numeric f32
//!   columns + categorical u8 columns). This is what the discretizer
//!   consumes.
//! * [`DiscreteDataset`] — everything binned to `u8` indices with known
//!   per-column arity. This is the *only* representation the CFS search
//!   and both DiCFS partitioning schemes touch; bin count is capped at
//!   [`DiscreteDataset::MAX_BINS`] to match the AOT kernel tile (B = 32).

use crate::core::{Error, Result};
use crate::data::schema::{FeatureKind, Schema};

/// One raw feature column.
#[derive(Debug, Clone)]
pub enum Column {
    /// Real-valued feature.
    Numeric(Vec<f32>),
    /// Categorical feature: value indices plus arity.
    Categorical { values: Vec<u8>, arity: u16 },
}

impl Column {
    /// Number of rows in the column.
    pub fn len(&self) -> usize {
        match self {
            Column::Numeric(v) => v.len(),
            Column::Categorical { values, .. } => values.len(),
        }
    }

    /// True when the column has no rows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The schema kind of this column.
    pub fn kind(&self) -> FeatureKind {
        match self {
            Column::Numeric(_) => FeatureKind::Numeric,
            Column::Categorical { arity, .. } => FeatureKind::Categorical { arity: *arity },
        }
    }
}

/// A raw (pre-discretization) dataset: mixed columns + class labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Human-readable name (used by the harness reports).
    pub name: String,
    /// Predictive feature columns, all the same length.
    pub features: Vec<Column>,
    /// Class labels, one per row.
    pub class: Vec<u8>,
    /// Number of distinct class labels.
    pub class_arity: u16,
}

impl Dataset {
    /// Validate internal consistency and build.
    pub fn new(
        name: impl Into<String>,
        features: Vec<Column>,
        class: Vec<u8>,
        class_arity: u16,
    ) -> Result<Self> {
        let n = class.len();
        for (i, c) in features.iter().enumerate() {
            if c.len() != n {
                return Err(Error::InvalidData(format!(
                    "column {i} has {} rows, class has {n}",
                    c.len()
                )));
            }
        }
        if let Some(&mx) = class.iter().max() {
            if u16::from(mx) >= class_arity {
                return Err(Error::InvalidData(format!(
                    "class label {mx} >= arity {class_arity}"
                )));
            }
        }
        Ok(Self {
            name: name.into(),
            features,
            class,
            class_arity,
        })
    }

    /// Number of rows (instances).
    pub fn num_rows(&self) -> usize {
        self.class.len()
    }

    /// Number of predictive features.
    pub fn num_features(&self) -> usize {
        self.features.len()
    }

    /// Derive the schema of this dataset.
    pub fn schema(&self) -> Schema {
        Schema::new(
            self.features.iter().map(|c| c.kind()).collect(),
            self.class_arity,
        )
    }
}

/// A fully discretized dataset: the CFS working representation.
///
/// `cols[f][r]` is the bin index of feature `f` at row `r`; `arities[f]`
/// is its bin count. All arities are ≤ [`Self::MAX_BINS`].
#[derive(Debug, Clone)]
pub struct DiscreteDataset {
    /// Dataset name, carried through from the raw dataset.
    pub name: String,
    /// Bin indices, column-major.
    pub cols: Vec<Vec<u8>>,
    /// Bin count per feature column.
    pub arities: Vec<u16>,
    /// Class labels.
    pub class: Vec<u8>,
    /// Number of class labels.
    pub class_arity: u16,
}

impl DiscreteDataset {
    /// Maximum bins per feature — matches the AOT kernel tile (B = 32).
    /// The MDL discretizer rarely produces more than ~10 cut points; the
    /// cap only bites on high-arity categorical features, which are
    /// re-binned by frequency (see `discretize::cap_arity`).
    pub const MAX_BINS: u16 = 32;

    /// Validate and build.
    pub fn new(
        name: impl Into<String>,
        cols: Vec<Vec<u8>>,
        arities: Vec<u16>,
        class: Vec<u8>,
        class_arity: u16,
    ) -> Result<Self> {
        if cols.len() != arities.len() {
            return Err(Error::InvalidData(format!(
                "{} columns but {} arities",
                cols.len(),
                arities.len()
            )));
        }
        let n = class.len();
        for (f, col) in cols.iter().enumerate() {
            if col.len() != n {
                return Err(Error::InvalidData(format!(
                    "column {f}: {} rows vs class {n}",
                    col.len()
                )));
            }
            let a = arities[f];
            if a == 0 || a > Self::MAX_BINS {
                return Err(Error::InvalidData(format!(
                    "column {f}: arity {a} outside 1..={}",
                    Self::MAX_BINS
                )));
            }
            if let Some(&mx) = col.iter().max() {
                if u16::from(mx) >= a {
                    return Err(Error::InvalidData(format!(
                        "column {f}: bin {mx} >= arity {a}"
                    )));
                }
            }
        }
        if u16::from(class.iter().copied().max().unwrap_or(0)) >= class_arity {
            return Err(Error::InvalidData("class label >= class arity".into()));
        }
        Ok(Self {
            name: name.into(),
            cols,
            arities,
            class,
            class_arity,
        })
    }

    /// Number of rows.
    pub fn num_rows(&self) -> usize {
        self.class.len()
    }

    /// Number of predictive features.
    pub fn num_features(&self) -> usize {
        self.cols.len()
    }

    /// Column accessor that treats [`crate::core::CLASS_ID`] as the class
    /// column — the correlation path addresses class/feature uniformly.
    pub fn column(&self, id: usize) -> (&[u8], u16) {
        if id == crate::core::CLASS_ID {
            (&self.class, self.class_arity)
        } else {
            (&self.cols[id], self.arities[id])
        }
    }

    /// Rough in-memory footprint in bytes (used by harness reports).
    pub fn footprint_bytes(&self) -> usize {
        self.cols.iter().map(|c| c.len()).sum::<usize>() + self.class.len()
    }

    /// A copy of the row range `range` of every column (and the class),
    /// keeping the arities of the full dataset.
    ///
    /// This is the versioning building block: the incremental-service
    /// tests and the workload-script replay discretize a dataset **once**
    /// (so the binning is frozen) and then reveal row slices of it —
    /// a base slice at registration, the rest as append deltas — which
    /// models instances arriving over time from the same distribution.
    ///
    /// Panics if `range` exceeds the row count (a caller bug, like an
    /// out-of-bounds index).
    pub fn slice_rows(&self, range: std::ops::Range<usize>) -> DiscreteDataset {
        assert!(
            range.start <= range.end && range.end <= self.num_rows(),
            "slice_rows {range:?} out of bounds for {} rows",
            self.num_rows()
        );
        // Field-wise construction is safe: every invariant `new` checks
        // (bin < arity, aligned lengths) is inherited from `self`.
        Self {
            name: self.name.clone(),
            cols: self.cols.iter().map(|c| c[range.clone()].to_vec()).collect(),
            arities: self.arities.clone(),
            class: self.class[range.clone()].to_vec(),
            class_arity: self.class_arity,
        }
    }

    /// A new dataset with `delta`'s rows appended after this dataset's —
    /// the registry-side half of the incremental-append path.
    ///
    /// The merged dataset keeps **this** dataset's arities (the binning
    /// is frozen at registration), so every delta bin index must already
    /// be valid under them; the merged data is re-validated through
    /// [`Self::new`], which rejects out-of-range delta bins or class
    /// labels and mismatched feature counts.
    pub fn append_rows(&self, delta: &DiscreteDataset) -> Result<DiscreteDataset> {
        if delta.num_features() != self.num_features() {
            return Err(Error::InvalidData(format!(
                "append has {} features, dataset has {}",
                delta.num_features(),
                self.num_features()
            )));
        }
        let cols: Vec<Vec<u8>> = self
            .cols
            .iter()
            .zip(&delta.cols)
            .map(|(base, extra)| {
                let mut c = base.clone();
                c.extend_from_slice(extra);
                c
            })
            .collect();
        let mut class = self.class.clone();
        class.extend_from_slice(&delta.class);
        Self::new(
            self.name.clone(),
            cols,
            self.arities.clone(),
            class,
            self.class_arity,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> DiscreteDataset {
        DiscreteDataset::new(
            "t",
            vec![vec![0, 1, 1, 0], vec![2, 0, 1, 2]],
            vec![2, 3],
            vec![0, 1, 1, 0],
            2,
        )
        .unwrap()
    }

    #[test]
    fn dataset_validates_row_counts() {
        let err = Dataset::new(
            "x",
            vec![Column::Numeric(vec![1.0, 2.0])],
            vec![0, 1, 0],
            2,
        );
        assert!(err.is_err());
    }

    #[test]
    fn dataset_validates_class_labels() {
        let err = Dataset::new("x", vec![], vec![0, 5], 2);
        assert!(err.is_err());
    }

    #[test]
    fn discrete_validates_bins_against_arity() {
        let err = DiscreteDataset::new("t", vec![vec![0, 3]], vec![2], vec![0, 0], 1);
        assert!(err.is_err());
    }

    #[test]
    fn discrete_rejects_oversized_arity() {
        let err = DiscreteDataset::new("t", vec![vec![0]], vec![33], vec![0], 1);
        assert!(err.is_err());
    }

    #[test]
    fn column_accessor_handles_class_id() {
        let d = tiny();
        let (c, a) = d.column(crate::core::CLASS_ID);
        assert_eq!(c, &[0, 1, 1, 0]);
        assert_eq!(a, 2);
        let (f1, a1) = d.column(1);
        assert_eq!(f1, &[2, 0, 1, 2]);
        assert_eq!(a1, 3);
    }

    #[test]
    fn slice_then_append_roundtrips() {
        let d = tiny();
        let base = d.slice_rows(0..3);
        let delta = d.slice_rows(3..4);
        assert_eq!(base.num_rows(), 3);
        assert_eq!(base.arities, d.arities, "slices keep the full arities");
        let merged = base.append_rows(&delta).unwrap();
        assert_eq!(merged.cols, d.cols);
        assert_eq!(merged.class, d.class);
        // Empty slices are fine (an append of zero rows is rejected at
        // the service layer, not here).
        assert_eq!(d.slice_rows(2..2).num_rows(), 0);
    }

    #[test]
    fn append_rejects_mismatched_deltas() {
        let d = tiny();
        // Wrong feature count.
        let narrow = DiscreteDataset::new("n", vec![vec![0]], vec![2], vec![0], 2).unwrap();
        assert!(d.append_rows(&narrow).is_err());
        // Delta bin out of range for the frozen base arity (column 0 has
        // arity 2, the delta uses bin 3).
        let bad = DiscreteDataset::new(
            "b",
            vec![vec![3], vec![0]],
            vec![4, 3],
            vec![0],
            2,
        )
        .unwrap();
        assert!(d.append_rows(&bad).is_err());
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn slice_rows_bounds_checked() {
        tiny().slice_rows(2..9);
    }

    #[test]
    fn schema_roundtrip() {
        let ds = Dataset::new(
            "x",
            vec![
                Column::Numeric(vec![1.0]),
                Column::Categorical {
                    values: vec![0],
                    arity: 4,
                },
            ],
            vec![0],
            2,
        )
        .unwrap();
        let s = ds.schema();
        assert_eq!(s.num_features(), 2);
        assert_eq!(s.kinds[1], FeatureKind::Categorical { arity: 4 });
    }
}
