//! Dataset scaling by duplication — the paper's §6 protocol.
//!
//! "with the aim of offering a comprehensive view of execution time
//! behaviour, Figure 3 shows results for sizes larger than the 100% of the
//! datasets. To achieve these sizes, the instances in each dataset were
//! duplicated as many times as necessary" — and Figure 4 does the same for
//! features. Percentages below 100 take a prefix sample.

use crate::data::columnar::{Column, Dataset};

/// Scale the number of instances to `pct`% of the original by prefix
/// sampling (< 100) or whole-dataset duplication + prefix (> 100).
pub fn scale_instances(ds: &Dataset, pct: usize) -> Dataset {
    let n = ds.num_rows();
    let target = (n * pct).div_ceil(100);
    let take = |col_len: usize| -> Vec<usize> {
        (0..target).map(|i| i % col_len).collect()
    };
    let idx = take(n);
    let features = ds
        .features
        .iter()
        .map(|c| match c {
            Column::Numeric(v) => Column::Numeric(idx.iter().map(|&i| v[i]).collect()),
            Column::Categorical { values, arity } => Column::Categorical {
                values: idx.iter().map(|&i| values[i]).collect(),
                arity: *arity,
            },
        })
        .collect();
    let class = idx.iter().map(|&i| ds.class[i]).collect();
    Dataset::new(
        format!("{}_{}i", ds.name, pct),
        features,
        class,
        ds.class_arity,
    )
    .expect("scaling preserves consistency")
}

/// Scale the number of features to `pct`% by column duplication (> 100) or
/// prefix selection (< 100). Duplicated columns are exact copies, as in the
/// paper — CFS sees them as perfectly redundant.
pub fn scale_features(ds: &Dataset, pct: usize) -> Dataset {
    let m = ds.num_features();
    let target = (m * pct).div_ceil(100).max(1);
    let features: Vec<Column> = (0..target).map(|i| ds.features[i % m].clone()).collect();
    Dataset::new(
        format!("{}_{}f", ds.name, pct),
        features,
        ds.class.clone(),
        ds.class_arity,
    )
    .expect("scaling preserves consistency")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{higgs_like, SynthConfig};

    fn base() -> Dataset {
        higgs_like(&SynthConfig {
            rows: 100,
            seed: 4,
            features: Some(6),
        })
    }

    #[test]
    fn upscale_instances_duplicates() {
        let ds = base();
        let big = scale_instances(&ds, 250);
        assert_eq!(big.num_rows(), 250);
        assert_eq!(big.num_features(), 6);
        // rows 0..100 repeat at 100..200
        assert_eq!(big.class[0], big.class[100]);
        assert_eq!(big.class[50], big.class[150]);
    }

    #[test]
    fn downscale_instances_prefix() {
        let ds = base();
        let small = scale_instances(&ds, 25);
        assert_eq!(small.num_rows(), 25);
        assert_eq!(&small.class[..], &ds.class[..25]);
    }

    #[test]
    fn upscale_features_copies_columns() {
        let ds = base();
        let wide = scale_features(&ds, 300);
        assert_eq!(wide.num_features(), 18);
        match (&wide.features[0], &wide.features[6]) {
            (Column::Numeric(a), Column::Numeric(b)) => assert_eq!(a, b),
            _ => panic!("expected numeric copies"),
        }
    }

    #[test]
    fn downscale_features_prefix() {
        let ds = base();
        let narrow = scale_features(&ds, 50);
        assert_eq!(narrow.num_features(), 3);
    }

    #[test]
    fn scale_100_is_identity_shape() {
        let ds = base();
        assert_eq!(scale_instances(&ds, 100).num_rows(), ds.num_rows());
        assert_eq!(scale_features(&ds, 100).num_features(), ds.num_features());
    }

    #[test]
    fn names_record_scaling() {
        let ds = base();
        assert_eq!(scale_instances(&ds, 200).name, "higgs_200i");
        assert_eq!(scale_features(&ds, 200).name, "higgs_200f");
    }
}
