//! Cluster topology + network cost model.
//!
//! Defaults mirror the paper's testbed (§6): up to 10 slave nodes,
//! 12 cores each, 10 GbE interconnect, Spark 1.6-era task overheads.

/// Simulated network characteristics.
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    /// Point-to-point bandwidth in bytes/second (10 GbE ≈ 1.25 GB/s).
    pub bandwidth_bytes_per_s: f64,
    /// Per-transfer latency in seconds.
    pub latency_s: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        Self {
            bandwidth_bytes_per_s: 1.25e9,
            latency_s: 1e-3,
        }
    }
}

impl NetworkModel {
    /// Time to shuffle `bytes` across a cluster of `nodes` nodes: the
    /// all-to-all redistribution moves the (nodes−1)/nodes fraction that
    /// lands off-node, with every node sending in parallel.
    pub fn shuffle_secs(&self, bytes: usize, nodes: usize) -> f64 {
        if bytes == 0 || nodes <= 1 {
            return 0.0;
        }
        let cross = bytes as f64 * (nodes as f64 - 1.0) / nodes as f64;
        self.latency_s + cross / (self.bandwidth_bytes_per_s * nodes as f64)
    }

    /// Time to broadcast `bytes` from the driver to `nodes` nodes.
    /// Spark's torrent broadcast *pipelines* blocks down a log2(nodes)
    /// tree: latency is paid once (pipeline fill ≈ 2 RTT), only the
    /// bandwidth term scales with the tree depth.
    pub fn broadcast_secs(&self, bytes: usize, nodes: usize) -> f64 {
        if bytes == 0 || nodes == 0 {
            return 0.0;
        }
        let hops = (nodes as f64).log2().ceil().max(1.0);
        2.0 * self.latency_s + bytes as f64 * hops / self.bandwidth_bytes_per_s
    }

    /// Time to collect `bytes` of results back to the driver.
    pub fn collect_secs(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.latency_s + bytes as f64 / self.bandwidth_bytes_per_s
    }
}

/// Virtual cluster topology for the simulated clock.
#[derive(Debug, Clone, Copy)]
pub struct ClusterConfig {
    /// Number of worker (slave) nodes.
    pub nodes: usize,
    /// Executor cores per node (paper: 12).
    pub cores_per_node: usize,
    /// Per-task launch overhead in seconds.
    ///
    /// Spark 1.6's real launch overhead is ~4 ms — about 3% of a task that
    /// scans a 128 MB block (≈140k rows of ECBDL14 per the paper's
    /// topology). Host-scale workloads are ~10³× smaller per task, so the
    /// default scales the overhead by the same factor to preserve the
    /// paper's overhead-to-compute *regime*; otherwise launch overhead
    /// would dominate every simulated stage in a way the paper's testbed
    /// never exhibited (see DESIGN.md §2 and EXPERIMENTS.md §Method).
    pub task_overhead_s: f64,
    /// Network model for shuffle/broadcast/collect accounting.
    pub net: NetworkModel,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            nodes: 10,
            cores_per_node: 12,
            task_overhead_s: 5e-6,
            net: NetworkModel::default(),
        }
    }
}

impl ClusterConfig {
    /// A cluster with `nodes` nodes and paper-default cores/overheads.
    pub fn with_nodes(nodes: usize) -> Self {
        Self {
            nodes,
            ..Self::default()
        }
    }

    /// Total executor slots.
    pub fn total_slots(&self) -> usize {
        (self.nodes * self.cores_per_node).max(1)
    }

    /// Spark's block-count heuristic for row-partitioned inputs: one
    /// partition per 64-row block, capped at 2× the cluster's slots.
    /// The block size is calibrated so per-task compute stays well above
    /// the launch overhead at host scale (see
    /// [`ClusterConfig::task_overhead_s`]). Shared by DiCFS-hp, RegCFS
    /// and the multi-query service so their defaults cannot drift apart.
    pub fn default_row_partitions(&self, rows: usize) -> usize {
        rows.div_ceil(64).clamp(1, 2 * self.total_slots())
    }

    /// Single-node, single-core "cluster" (the WEKA baseline topology).
    pub fn single_node() -> Self {
        Self {
            nodes: 1,
            cores_per_node: 1,
            task_overhead_s: 0.0,
            net: NetworkModel::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_testbed() {
        let c = ClusterConfig::default();
        assert_eq!(c.nodes, 10);
        assert_eq!(c.cores_per_node, 12);
        assert_eq!(c.total_slots(), 120);
    }

    #[test]
    fn shuffle_zero_cases() {
        let net = NetworkModel::default();
        assert_eq!(net.shuffle_secs(0, 10), 0.0);
        assert_eq!(net.shuffle_secs(1 << 20, 1), 0.0); // single node: no net
    }

    #[test]
    fn shuffle_scales_with_bytes() {
        let net = NetworkModel::default();
        let a = net.shuffle_secs(1 << 20, 4);
        let b = net.shuffle_secs(1 << 24, 4);
        assert!(b > a);
    }

    #[test]
    fn broadcast_grows_with_nodes() {
        let net = NetworkModel::default();
        let two = net.broadcast_secs(1 << 24, 2);
        let ten = net.broadcast_secs(1 << 24, 10);
        assert!(ten > two);
    }

    #[test]
    fn more_nodes_shuffle_faster_at_fixed_bytes() {
        // aggregate bandwidth grows with node count
        let net = NetworkModel::default();
        let gib = 1usize << 30;
        assert!(net.shuffle_secs(gib, 10) < net.shuffle_secs(gib, 2));
    }
}
