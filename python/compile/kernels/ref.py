"""Pure-jnp correctness oracles for the DiCFS numeric path.

These are the ground truth the Pallas kernels (ctable.py, su.py) are tested
against, and the same math the rust NativeEngine re-implements. Everything is
expressed over *discretized* features: a feature value is a bin index in
``[0, num_bins)`` stored as int32.

Conventions (mirrored by rust/src/correlation/):
  * contingency table ``ct[i, j]`` counts instances with ``x == i`` and
    ``y == j``; masked instances (``valid == 0``) contribute nothing.
  * symmetrical uncertainty ``SU = 2 * (H(X) + H(Y) - H(X,Y)) / (H(X) + H(Y))``
    with ``SU = 0`` when ``H(X) + H(Y) == 0`` (both features constant) and
    when the table is empty — matching WEKA's
    ``ContingencyTables.symmetricalUncertainty``.
  * entropies are base-2.
"""

import jax.numpy as jnp


def ctable_ref(x, y, valid, num_bins):
    """Batched contingency tables.

    Args:
      x: int32[P, N] bin indices of the first feature of each pair.
      y: int32[P, N] bin indices of the second feature of each pair.
      valid: f32[N] mask; 0.0 rows are padding and are not counted.
      num_bins: static bin count B.

    Returns:
      f32[P, B, B] counts.
    """
    bins = jnp.arange(num_bins, dtype=jnp.int32)
    # one-hot along a new trailing axis: [P, N, B]
    ox = (x[:, :, None] == bins[None, None, :]).astype(jnp.float32)
    oy = (y[:, :, None] == bins[None, None, :]).astype(jnp.float32)
    ox = ox * valid[None, :, None]
    # [P, B, N] @ [P, N, B] -> [P, B, B]
    return jnp.einsum("pnb,pnc->pbc", ox, oy)


def entropies_ref(ct):
    """Marginal and joint base-2 entropies of a batch of tables.

    Args:
      ct: f32[P, B, B] contingency tables.

    Returns:
      (hx, hy, hxy): three f32[P] arrays. Empty tables yield 0 entropies.
    """
    total = jnp.sum(ct, axis=(1, 2))
    safe = jnp.where(total > 0, total, 1.0)
    pxy = ct / safe[:, None, None]
    px = jnp.sum(pxy, axis=2)
    py = jnp.sum(pxy, axis=1)

    def ent(p, axes):
        plogp = jnp.where(p > 0, p * jnp.log2(jnp.where(p > 0, p, 1.0)), 0.0)
        return -jnp.sum(plogp, axis=axes)

    return ent(px, (1,)), ent(py, (1,)), ent(pxy, (1, 2))


def su_from_ctable_ref(ct):
    """Batched symmetrical uncertainty from contingency tables.

    Args:
      ct: f32[P, B, B].

    Returns:
      f32[P] SU values in [0, 1].
    """
    hx, hy, hxy = entropies_ref(ct)
    denom = hx + hy
    su = 2.0 * (hx + hy - hxy) / jnp.where(denom > 0, denom, 1.0)
    total = jnp.sum(ct, axis=(1, 2))
    return jnp.where((denom > 0) & (total > 0), su, 0.0)


def su_ref(x, y, valid, num_bins):
    """Fused oracle: SU of each feature pair directly from bin indices."""
    return su_from_ctable_ref(ctable_ref(x, y, valid, num_bins))
