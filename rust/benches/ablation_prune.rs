//! Ablation for sketch-then-verify pruning (DESIGN.md §16): exact SU
//! cells scanned with `--prune auto` vs the exact baseline (`off`).
//!
//! Workload: the regime the optimization targets — a handful of
//! genuinely relevant features over a mass of hopeless high-cardinality
//! noise. Six exact class copies pin the capacity-5 queue cut at merit
//! 1.0 through every expansion, while each noise column's sound SU
//! upper bound (`≤ 2·H(C)/(H(X)+H(C))` with a skewed binary class and
//! arity-64 noise ≈ 0.08) stays below the prune margin `√(k²+1) − k`
//! down to the deepest head the stop rule reaches (k = 5 → 0.099).
//! Every noise candidate is therefore pruned at every depth, so the
//! exact-pair count collapses from ~16·m to a constant — the selection
//! itself stays bit-identical (asserted here and proptest-enforced).
//!
//! Asserted acceptance bars (the ISSUE's):
//! * **Equal selections**: auto ≡ off — same subset, bit-identical
//!   merit — on every shape, sequential and hp.
//! * **Exact-cell drop**: on the wide and ultrawide shapes, exact SU
//!   cells (`correlations_computed × rows`) drop ≥ 5× (≥ 10× at
//!   `DICFS_BENCH_SCALE ≥ 1`).
//! * **Wall-clock**: at scale ≥ 1, the auto run is no slower than the
//!   baseline (small scales are too noisy to gate).
//! * The `ultrawide_like` synth preset rides along equality-gated: its
//!   reduction is reported but not floored (pruning may decline).
//!
//! Output: table + `bench_out/ablation_prune.csv` +
//! `bench_out/BENCH_prune.json` (sampled_cells, exact_cells,
//! pruned_candidates, per-shape reduction).

use std::sync::Arc;
use std::time::Instant;

use dicfs::cfs::best_first::{CfsConfig, PruneMode};
use dicfs::cfs::SequentialCfs;
use dicfs::core::SelectionResult;
use dicfs::data::columnar::DiscreteDataset;
use dicfs::data::synth::{ultrawide_like, SynthConfig};
use dicfs::dicfs::{DiCfs, DiCfsConfig, Partitioning};
use dicfs::discretize::discretize_dataset;
use dicfs::harness::{bench_scale, report};
use dicfs::util::chart::table;
use dicfs::util::XorShift64Star;

/// Six exact class copies + uniform arity-64 noise over a 4%-minority
/// binary class (see the module docs for why these constants make the
/// prune margin provable, not incidental).
fn structured(name: &str, rows: usize, features: usize, seed: u64) -> Arc<DiscreteDataset> {
    const COPIES: usize = 6;
    const NOISE_ARITY: u16 = 64;
    let mut rng = XorShift64Star::new(seed);
    let class: Vec<u8> = (0..rows).map(|_| u8::from(rng.next_below(25) == 0)).collect();
    let mut cols: Vec<Vec<u8>> = Vec::with_capacity(features);
    let mut arities: Vec<u16> = Vec::with_capacity(features);
    for f in 0..features {
        if f < COPIES {
            cols.push(class.clone());
            arities.push(2);
        } else {
            cols.push((0..rows).map(|_| rng.next_below(NOISE_ARITY as u64) as u8).collect());
            arities.push(NOISE_ARITY);
        }
    }
    Arc::new(DiscreteDataset::new(name.to_string(), cols, arities, class, 2).unwrap())
}

struct Run {
    result: SelectionResult,
    secs: f64,
}

fn seq_run(dd: &Arc<DiscreteDataset>, mode: PruneMode) -> Run {
    let cfg = CfsConfig {
        locally_predictive: false,
        prune: mode,
        ..CfsConfig::default()
    };
    let t = Instant::now();
    let result = SequentialCfs::new(cfg).select_discrete(dd);
    Run {
        result,
        secs: t.elapsed().as_secs_f64(),
    }
}

fn hp_run(dd: &Arc<DiscreteDataset>, mode: PruneMode) -> Run {
    let mut cfg = DiCfsConfig::for_scheme(Partitioning::Horizontal, 3);
    cfg.cfs.locally_predictive = false;
    cfg.cfs.prune = mode;
    let t = Instant::now();
    let result = DiCfs::native(cfg).select(dd).result;
    Run {
        result,
        secs: t.elapsed().as_secs_f64(),
    }
}

struct Row {
    shape: &'static str,
    rows: usize,
    features: usize,
    off_exact_cells: u64,
    auto_exact_cells: u64,
    sampled_cells: u64,
    pruned_candidates: usize,
    reduction: f64,
    off_secs: f64,
    auto_secs: f64,
    gated: bool,
}

fn measure(
    shape: &'static str,
    dd: &Arc<DiscreteDataset>,
    gated: bool,
    run: impl Fn(&Arc<DiscreteDataset>, PruneMode) -> Run,
) -> Row {
    let off = run(dd, PruneMode::Off);
    let auto = run(dd, PruneMode::Auto);
    assert_eq!(
        auto.result.selected, off.result.selected,
        "{shape}: pruned selection diverged from exact"
    );
    assert_eq!(
        auto.result.merit.to_bits(),
        off.result.merit.to_bits(),
        "{shape}: merit not bit-identical"
    );
    assert_eq!(off.result.pruned_candidates, 0, "{shape}: off pruned");
    assert_eq!(off.result.sampled_cells, 0, "{shape}: off sketched");
    let n = dd.num_rows() as u64;
    let off_exact_cells = off.result.correlations_computed as u64 * n;
    let auto_exact_cells = auto.result.correlations_computed as u64 * n;
    assert!(
        auto_exact_cells <= off_exact_cells,
        "{shape}: pruning increased exact work ({auto_exact_cells} > {off_exact_cells})"
    );
    Row {
        shape,
        rows: dd.num_rows(),
        features: dd.num_features(),
        off_exact_cells,
        auto_exact_cells,
        sampled_cells: auto.result.sampled_cells,
        pruned_candidates: auto.result.pruned_candidates,
        reduction: off_exact_cells as f64 / auto_exact_cells.max(1) as f64,
        off_secs: off.secs,
        auto_secs: auto.secs,
        gated,
    }
}

fn main() {
    let scale = bench_scale();
    println!("== Ablation: sketch-then-verify pruning vs exact baseline (scale {scale}) ==\n");

    let rows = |base: usize| ((base as f64 * scale) as usize).max(400);
    let mut out_rows: Vec<Row> = Vec::new();

    // Headline shapes (cell-reduction gated): sequential search.
    let wide = structured("wide", rows(4_000), 400, 11);
    out_rows.push(measure("wide-seq", &wide, true, seq_run));
    let ultra = structured("ultrawide", rows(1_200), 2_000, 13);
    out_rows.push(measure("ultrawide-seq", &ultra, true, seq_run));
    // The hp lowering prunes identically (bit-identical sketch tables).
    out_rows.push(measure("wide-hp", &wide, true, hp_run));

    // The ultrawide synth preset rides along equality-gated only: its
    // class structure is the generator's, so pruning may win less (or
    // decline); the bar is exactness and no extra exact work.
    let preset_raw = ultrawide_like(&SynthConfig {
        rows: ((120.0 * scale) as usize).max(60),
        seed: 17,
        features: None,
    });
    let preset = Arc::new(discretize_dataset(&preset_raw).unwrap());
    out_rows.push(measure("ultrawide-preset-seq", &preset, false, seq_run));

    let floor = if scale >= 1.0 { 10.0 } else { 5.0 };
    for r in &out_rows {
        if !r.gated {
            continue;
        }
        assert!(
            r.reduction >= floor,
            "{}: exact cells dropped only {:.1}x (< {floor}x): {} -> {}",
            r.shape,
            r.reduction,
            r.off_exact_cells,
            r.auto_exact_cells
        );
        assert!(r.pruned_candidates > 0, "{}: nothing pruned", r.shape);
        assert!(r.sampled_cells > 0, "{}: nothing sketched", r.shape);
        if scale >= 1.0 {
            assert!(
                r.auto_secs <= r.off_secs * 1.10,
                "{}: pruned run slower ({:.3}s vs {:.3}s)",
                r.shape,
                r.auto_secs,
                r.off_secs
            );
        }
    }

    let trows: Vec<Vec<String>> = out_rows
        .iter()
        .map(|r| {
            vec![
                r.shape.to_string(),
                format!("{}x{}", r.rows, r.features),
                r.off_exact_cells.to_string(),
                r.auto_exact_cells.to_string(),
                r.sampled_cells.to_string(),
                r.pruned_candidates.to_string(),
                format!("{:.1}x", r.reduction),
                format!("{:.3}/{:.3}", r.auto_secs, r.off_secs),
            ]
        })
        .collect();
    println!(
        "{}",
        table(
            &[
                "shape",
                "rows x features",
                "exact cells (off)",
                "exact cells (auto)",
                "sampled cells",
                "pruned",
                "reduction",
                "secs (auto/off)",
            ],
            &trows
        )
    );

    let csv: Vec<Vec<String>> = out_rows
        .iter()
        .map(|r| {
            vec![
                r.shape.to_string(),
                r.rows.to_string(),
                r.features.to_string(),
                r.off_exact_cells.to_string(),
                r.auto_exact_cells.to_string(),
                r.sampled_cells.to_string(),
                r.pruned_candidates.to_string(),
                format!("{:.4}", r.reduction),
                format!("{:.6}", r.off_secs),
                format!("{:.6}", r.auto_secs),
            ]
        })
        .collect();
    let path = report::write_csv(
        "ablation_prune.csv",
        &[
            "shape",
            "rows",
            "features",
            "off_exact_cells",
            "auto_exact_cells",
            "sampled_cells",
            "pruned_candidates",
            "reduction",
            "off_secs",
            "auto_secs",
        ],
        &csv,
    );

    // Machine-readable perf trajectory (one JSON per bench run).
    let shapes_json: Vec<String> = out_rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "    {{\"shape\": \"{}\", \"rows\": {}, \"features\": {}, ",
                    "\"exact_cells_off\": {}, \"exact_cells_auto\": {}, ",
                    "\"sampled_cells\": {}, \"pruned_candidates\": {}, ",
                    "\"reduction\": {:.4}, \"off_secs\": {:.6}, \"auto_secs\": {:.6}}}"
                ),
                r.shape,
                r.rows,
                r.features,
                r.off_exact_cells,
                r.auto_exact_cells,
                r.sampled_cells,
                r.pruned_candidates,
                r.reduction,
                r.off_secs,
                r.auto_secs
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"prune\",\n  \"shapes\": [\n{}\n  ]\n}}\n",
        shapes_json.join(",\n")
    );
    let json_path = report::out_dir().join("BENCH_prune.json");
    std::fs::write(&json_path, json).expect("write BENCH_prune.json");

    println!("ablation_prune: PASS (equal selections, >= {floor}x fewer exact SU cells)");
    println!("  data: {}", path.display());
    println!("  perf trajectory: {}\n", json_path.display());
}
