//! `dicfs` — the DiCFS command-line launcher (L3 leader entrypoint).
//!
//! Subcommands:
//! * `select`   — run feature selection (sequential / DiCFS-hp / DiCFS-vp)
//!                on a synthetic family or a CSV file.
//! * `generate` — emit a synthetic workload as CSV, or `--describe` to
//!                print the Table-1 reproduction.
//! * `compare`  — run all three variants, verify the paper's equivalence
//!                claim, and print timings + cluster metrics.
//! * `queries`  — multi-query service driver: replay a multi-tenant
//!                workload script against one long-lived service with
//!                cross-query SU caching (see `dicfs::serve::script`).
//! * `bench`    — regenerate a paper figure/table (also available via
//!                `cargo bench`).
//!
//! Argument parsing is hand-rolled (`--key value` pairs) since only the
//! `xla` crate closure is vendored in this environment.

use std::collections::HashMap;
use std::process::ExitCode;
use std::sync::Arc;

use dicfs::cfs::{CfsConfig, PruneMode, SequentialCfs};
use dicfs::data::synth::{by_name, SynthConfig, FAMILIES};
use dicfs::dicfs::{DiCfs, DiCfsConfig, Partitioning};
use dicfs::discretize::discretize_dataset;
use dicfs::harness;
use dicfs::runtime::{NativeEngine, SuEngine, TiledEngine};
use dicfs::util::timer::timed;

const USAGE: &str = "\
dicfs — Distributed Correlation-Based Feature Selection (paper reproduction)

USAGE:
  dicfs select   [--family NAME | --csv FILE] [--partitioning seq|hp|vp|auto]
                 [--nodes N] [--engine native|tiled|auto] [--partitions P]
                 [--rows N] [--features M] [--seed S] [--prune auto|off]
                 [--workers-proc N [--speculative true]]
  dicfs generate --family NAME --rows N [--features M] [--seed S] --out FILE
  dicfs generate --describe
  dicfs compare  [--family NAME] [--rows N] [--features M] [--nodes N]
  dicfs queries  --script FILE [--nodes N] [--concurrency C]
                 [--max-inflight J] [--engine native|tiled|auto] [--verify]
                 [--cache-budget BYTES|P%] [--tenant-weight W]
  dicfs bench    --target fig3|fig4|fig5|table2|ondemand|partitions|planner
                 [--scale X]

`--partitioning` defaults to `auto`: the adaptive planner chooses hp or
vp per correlation batch (cost model + measured feedback) and reports
every decision. `--scheme` is accepted as an alias.

`--engine` picks the SU kernel: `native` (scalar), `tiled`
(cache-blocked batch kernel, bit-identical results), or `auto` (the
default — under adaptive partitioning the planner also prices the
engine per batch and logs the winner; `pjrt` with the feature built).

`--prune` controls sketch-then-verify candidate pruning (DESIGN.md §16):
`auto` (the default) lets the search skip best-first candidates whose
sampled SU upper bound provably cannot survive the queue cut, with all
survivors verified exactly — selections are bit-identical to `off`,
which disables sketching entirely.

`--workers-proc N` runs the correlation jobs on N worker OS processes
speaking a binary protocol over Unix sockets (results are bit-identical
to the in-process backend); shuffle bytes are then *measured* and the
network model is calibrated from the observed transfers.
`--speculative true` additionally duplicates straggler tasks onto idle
workers.

FAMILIES: ecbdl14, higgs, kddcup99, epsilon (Table 1 of the paper),
          wide (features >> rows, for the planner harness),
          ultrawide (>=50k features over few rows, for the pruning path)

A `queries` script declares tenant datasets and the traffic over them —
queries, `append` directives that ingest new instances mid-workload
(cached SU state is *upgraded* from the delta rows, never recomputed;
`warm=true` warm-restarts a search from the previous winner), and
`retire NAME` which unregisters a tenant and frees its cache. Datasets
take `budget=BYTES|P%` (SU-cache byte budget; percent of the worst-case
fully-warmed cache) and `weight=W` (deficit-round-robin share);
`--cache-budget` / `--tenant-weight` set the defaults. Queries take
`algo=cfs|mrmr|relieff` (default cfs) — all three selectors share one
measure-keyed correlation cache per dataset, so an mRMR query reuses
the contingency tables a CFS query already paid for, e.g.:

  dataset logs family=kddcup99 rows=4000 features=20 seed=7 scheme=hp
  query logs repeat=3
  query logs max_fails=3 locally_predictive=false
  query logs algo=mrmr
  append logs rows=800
  query logs warm=true
";

fn parse_flags(args: &[String]) -> Result<HashMap<String, String>, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let k = args[i]
            .strip_prefix("--")
            .ok_or_else(|| format!("expected --flag, got {:?}", args[i]))?;
        if k == "describe" || k == "verify" {
            flags.insert(k.to_string(), "true".to_string());
            i += 1;
            continue;
        }
        let v = args
            .get(i + 1)
            .ok_or_else(|| format!("--{k} needs a value"))?;
        flags.insert(k.to_string(), v.clone());
        i += 2;
    }
    Ok(flags)
}

fn get_usize(flags: &HashMap<String, String>, key: &str, default: usize) -> usize {
    flags
        .get(key)
        .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} must be an integer")))
        .unwrap_or(default)
}

fn load_dataset(flags: &HashMap<String, String>) -> dicfs::data::Dataset {
    if let Some(path) = flags.get("csv") {
        dicfs::data::csv::read_csv(std::path::Path::new(path)).expect("csv load")
    } else {
        let family = flags.get("family").map(String::as_str).unwrap_or("higgs");
        assert!(FAMILIES.contains(&family), "unknown family {family}");
        by_name(
            family,
            &SynthConfig {
                rows: get_usize(flags, "rows", 10_000),
                seed: get_usize(flags, "seed", 1) as u64,
                features: flags.get("features").map(|v| v.parse().expect("--features")),
            },
        )
    }
}

/// Resolve `--engine` into the SU engine pool the run uses. `auto` (the
/// default) is the `[native, tiled]` pool: under adaptive partitioning
/// the planner prices every correlation batch across both engines and
/// logs the winner; fixed schemes pin to the first (native) entry. A
/// named engine yields a single-entry pool that every batch runs on.
fn make_engine_pool(flags: &HashMap<String, String>) -> Vec<Arc<dyn SuEngine>> {
    match flags.get("engine").map(String::as_str).unwrap_or("auto") {
        "auto" => vec![Arc::new(NativeEngine), Arc::new(TiledEngine::new())],
        "native" => vec![Arc::new(NativeEngine)],
        "tiled" => vec![Arc::new(TiledEngine::new())],
        #[cfg(feature = "pjrt")]
        "pjrt" => vec![Arc::new(
            dicfs::runtime::pjrt::PjrtEngine::from_default_dir()
                .expect("pjrt engine (run `make artifacts`?)"),
        )],
        other => panic!("unknown engine {other} (build with --features pjrt?)"),
    }
}

fn cmd_select(flags: &HashMap<String, String>) {
    let ds = load_dataset(flags);
    println!(
        "dataset: {} ({} rows x {} features, {} classes)",
        ds.name,
        ds.num_rows(),
        ds.num_features(),
        ds.class_arity
    );
    let (dd, disc_secs) = timed(|| Arc::new(discretize_dataset(&ds).unwrap()));
    println!("discretized in {disc_secs:.2}s");

    // `--partitioning` is the documented flag; `--scheme` stays as an
    // alias for older invocations. Default: the adaptive planner.
    let scheme = flags
        .get("partitioning")
        .or_else(|| flags.get("scheme"))
        .map(String::as_str)
        .unwrap_or("auto");
    let nodes = get_usize(flags, "nodes", 10);
    let prune = flags
        .get("prune")
        .map(|s| {
            PruneMode::parse(s).unwrap_or_else(|| panic!("--prune must be auto|off, got {s:?}"))
        })
        .unwrap_or(PruneMode::Auto);
    match scheme {
        "seq" => {
            let cfs = SequentialCfs::new(CfsConfig {
                prune,
                ..CfsConfig::default()
            });
            let (r, secs) = timed(|| cfs.select_discrete(&dd));
            print_result(&r, secs, None);
        }
        "hp" | "vp" | "auto" => {
            let partitioning = match scheme {
                "hp" => Partitioning::Horizontal,
                "vp" => Partitioning::Vertical,
                _ => Partitioning::Auto,
            };
            let mut cfg = DiCfsConfig::for_scheme(partitioning, nodes);
            cfg.cfs.prune = prune;
            if let Some(p) = flags.get("partitions") {
                cfg.num_partitions = Some(p.parse().expect("--partitions"));
            }
            if let Some(w) = flags.get("workers-proc") {
                cfg.workers_proc = Some(w.parse().expect("--workers-proc"));
                cfg.speculative = flags
                    .get("speculative")
                    .map(|v| v == "true")
                    .unwrap_or(false);
            }
            let run = DiCfs::with_engine_pool(cfg, make_engine_pool(flags)).select(&dd);
            print_result(&run.result, run.wall_secs, Some(&run));
        }
        other => panic!("unknown partitioning {other} (seq|hp|vp|auto)"),
    }
}

fn print_result(
    r: &dicfs::core::SelectionResult,
    wall: f64,
    run: Option<&dicfs::dicfs::DiCfsRun>,
) {
    println!("\nselected {} features: {:?}", r.selected.len(), r.selected);
    println!("merit: {:.6}", r.merit);
    println!(
        "iterations: {}, correlations computed: {}, locally-predictive added: {:?}",
        r.iterations, r.correlations_computed, r.locally_predictive_added
    );
    if r.pruned_candidates > 0 || r.sampled_cells > 0 {
        println!(
            "pruning: {} candidates skipped via {} sketch cells (selections exact)",
            r.pruned_candidates, r.sampled_cells
        );
    }
    println!("wall: {wall:.3}s");
    if let Some(run) = run {
        println!(
            "cluster sim ({} tasks, {} stages): compute {:.3}s + network {:.3}s + driver {:.3}s = {:.3}s",
            run.metrics.total_tasks(),
            run.metrics.stages.len(),
            run.sim.compute_secs,
            run.sim.network_secs,
            run.sim.driver_secs,
            run.sim.total()
        );
        println!(
            "shuffle {} B, broadcast {} B, retries {}",
            run.metrics.total_shuffle_bytes(),
            run.metrics.total_broadcast_bytes(),
            run.metrics.total_retries()
        );
        let measured = run.metrics.total_measured_shuffle_bytes();
        if measured > 0 {
            println!("measured shuffle (wire): {measured} B");
        }
        if let Some(net) = &run.calibrated_net {
            println!(
                "calibrated network: {:.3e} B/s bandwidth, {:.3e}s latency",
                net.bandwidth_bytes_per_s, net.latency_s
            );
        }
        if !run.decisions.is_empty() {
            let hp = run
                .decisions
                .iter()
                .filter(|d| d.strategy == dicfs::dicfs::plan::Strategy::Hp)
                .count();
            println!(
                "planner: {} batches ({} hp, {} vp)",
                run.decisions.len(),
                hp,
                run.decisions.len() - hp
            );
            for d in &run.decisions {
                println!("  {}", d.summary());
            }
        }
    }
}

fn cmd_generate(flags: &HashMap<String, String>) {
    if flags.contains_key("describe") {
        println!("{}", harness::workload::table1());
        return;
    }
    let ds = load_dataset(flags);
    let out = flags.get("out").expect("--out FILE required");
    dicfs::data::csv::write_csv(&ds, std::path::Path::new(out)).expect("csv write");
    println!(
        "wrote {} ({} rows x {} features)",
        out,
        ds.num_rows(),
        ds.num_features()
    );
}

fn cmd_compare(flags: &HashMap<String, String>) {
    let ds = load_dataset(flags);
    let dd = Arc::new(discretize_dataset(&ds).unwrap());
    let nodes = get_usize(flags, "nodes", 10);

    let (seq, seq_secs) = timed(|| SequentialCfs::default().select_discrete(&dd));
    let hp = DiCfs::native(DiCfsConfig::for_scheme(Partitioning::Horizontal, nodes)).select(&dd);
    let vp = DiCfs::native(DiCfsConfig::for_scheme(Partitioning::Vertical, nodes)).select(&dd);
    let auto = DiCfs::native(DiCfsConfig::for_scheme(Partitioning::Auto, nodes)).select(&dd);

    let auto_hp = auto
        .decisions
        .iter()
        .filter(|d| d.strategy == dicfs::dicfs::plan::Strategy::Hp)
        .count();
    let rows = vec![
        vec![
            "sequential (WEKA)".to_string(),
            format!("{seq_secs:.3}"),
            "-".to_string(),
            format!("{:?}", seq.selected),
        ],
        vec![
            "DiCFS-hp".to_string(),
            format!("{:.3}", hp.wall_secs),
            format!("{:.3}", hp.sim.total()),
            format!("{:?}", hp.result.selected),
        ],
        vec![
            "DiCFS-vp".to_string(),
            format!("{:.3}", vp.wall_secs),
            format!("{:.3}", vp.sim.total()),
            format!("{:?}", vp.result.selected),
        ],
        vec![
            format!(
                "DiCFS-auto ({}hp/{}vp)",
                auto_hp,
                auto.decisions.len() - auto_hp
            ),
            format!("{:.3}", auto.wall_secs),
            format!("{:.3}", auto.sim.total()),
            format!("{:?}", auto.result.selected),
        ],
    ];
    println!(
        "{}",
        dicfs::util::chart::table(
            &["variant", "wall s", &format!("sim s ({nodes} nodes)"), "selected"],
            &rows
        )
    );
    let ok = hp.result.selected == seq.selected
        && vp.result.selected == seq.selected
        && auto.result.selected == seq.selected;
    println!(
        "equivalence (paper's quality claim): {}",
        if ok { "EXACT MATCH" } else { "MISMATCH!" }
    );
    assert!(ok);
}

fn cmd_queries(flags: &HashMap<String, String>) {
    let path = flags.get("script").expect("--script FILE required");
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("cannot read script {path:?}: {e}"));
    let script = match dicfs::serve::script::parse(&text) {
        Ok(s) => s,
        Err(e) => panic!("script error: {e}"),
    };
    let cache_budget = flags.get("cache-budget").map(|s| {
        dicfs::serve::script::BudgetSpec::parse(s)
            .unwrap_or_else(|e| panic!("--cache-budget: {e}"))
    });
    let tenant_weight = flags
        .get("tenant-weight")
        .map(|s| {
            s.parse::<f64>()
                .unwrap_or_else(|_| panic!("--tenant-weight: not a number: {s:?}"))
        })
        .unwrap_or(1.0);
    let opts = dicfs::serve::script::ReplayOptions {
        nodes: get_usize(flags, "nodes", 10),
        max_inflight_jobs: get_usize(flags, "max-inflight", 2),
        concurrency: get_usize(flags, "concurrency", 4),
        verify: flags.contains_key("verify"),
        cache_budget,
        tenant_weight,
    };
    println!(
        "replaying {} dataset(s), {} directive(s) (concurrency {}, max in-flight jobs {})\n",
        script.datasets.len(),
        script.ops.len(),
        opts.concurrency,
        opts.max_inflight_jobs
    );
    let _ = dicfs::serve::script::replay(&script, &opts, make_engine_pool(flags));
}

fn cmd_bench(flags: &HashMap<String, String>) {
    let scale: f64 = flags
        .get("scale")
        .map(|v| v.parse().expect("--scale"))
        .unwrap_or_else(harness::bench_scale);
    match flags.get("target").map(String::as_str) {
        Some("fig3") => {
            let rows = harness::fig3::run(scale, &[25, 50, 75, 100, 150, 200], 10);
            harness::fig3::emit(&rows);
        }
        Some("fig4") => {
            let rows = harness::fig4::run(scale, &[50, 100, 200, 400], 10);
            harness::fig4::emit(&rows);
        }
        Some("fig5") => {
            let curves = harness::fig5::run(scale, &[2, 3, 4, 5, 6, 7, 8, 9, 10], 10);
            harness::fig5::emit(&curves);
        }
        Some("table2") => {
            let rows = harness::table2::run(scale, 10);
            harness::table2::emit(&rows);
        }
        Some("ondemand") => {
            let rows = harness::ablation::run_ondemand(scale);
            harness::ablation::emit_ondemand(&rows);
        }
        Some("partitions") => {
            let rows =
                harness::ablation::run_partitions(scale, &[25, 50, 100, 250, 500, 1000, 2000], 10);
            harness::ablation::emit_partitions(&rows);
        }
        Some("planner") => {
            let rows = harness::planner::run(scale, 10);
            harness::planner::emit(&rows);
        }
        other => panic!(
            "--target must be one of fig3/fig4/fig5/table2/ondemand/partitions/planner, got {other:?}"
        ),
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // Hidden worker mode: the multi-process backend re-invokes this
    // binary as `dicfs --worker <socket>` (before any other parsing —
    // workers must never fall through to the user-facing CLI).
    if args.first().map(String::as_str) == Some("--worker") {
        let Some(socket) = args.get(1) else {
            eprintln!("--worker needs a socket path");
            return ExitCode::FAILURE;
        };
        std::process::exit(dicfs::sparklet::remote::worker_main(socket));
    }
    let Some((cmd, rest)) = args.split_first() else {
        eprint!("{USAGE}");
        return ExitCode::FAILURE;
    };
    let flags = match parse_flags(rest) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    match cmd.as_str() {
        "select" => cmd_select(&flags),
        "generate" => cmd_generate(&flags),
        "compare" => cmd_compare(&flags),
        "queries" => cmd_queries(&flags),
        "bench" => cmd_bench(&flags),
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            eprintln!("unknown command {other:?}\n\n{USAGE}");
            return ExitCode::FAILURE;
        }
    }
    ExitCode::SUCCESS
}
