//! Exact native engine: u64 counts, f64 entropies.
//!
//! This is the deterministic reference the equivalence invariant runs on,
//! and the same math as `python/compile/kernels/ref.py` (pinned by the
//! golden fixtures). It is also heavily optimized — see DESIGN.md §7: the
//! ctable inner loop is the L3 hot path when PJRT is disabled.

use crate::correlation::su::su_from_table;
use crate::correlation::ContingencyTable;
use crate::runtime::{ColumnPair, SuEngine};

/// Pure-rust engine (default).
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeEngine;

impl SuEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn ctables(
        &self,
        pairs: &[ColumnPair<'_>],
        rows: std::ops::Range<usize>,
    ) -> Vec<ContingencyTable> {
        pairs
            .iter()
            .map(|p| {
                ContingencyTable::from_columns_range(
                    p.x,
                    p.bins_x,
                    p.y,
                    p.bins_y,
                    rows.clone(),
                )
            })
            .collect()
    }

    fn su_from_tables(&self, tables: &[&ContingencyTable]) -> Vec<f64> {
        tables.iter().map(|&t| su_from_table(t)).collect()
    }

    /// Fused per-pair path: count and finish each pair as it streams by,
    /// instead of materializing the whole batch's `Vec<ContingencyTable>`
    /// plus a reference `Vec` first (the default two-phase composition).
    /// Bit-identical by construction — the per-pair table and the
    /// `su_from_table` finish are exactly the ones the two-phase path
    /// would build, only their lifetimes are per-iteration.
    fn su_from_column_pairs(&self, pairs: &[ColumnPair<'_>]) -> Vec<f64> {
        pairs
            .iter()
            .map(|p| {
                let t = ContingencyTable::from_columns(p.x, p.bins_x, p.y, p.bins_y);
                su_from_table(&t)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift64Star;

    fn random_cols(seed: u64, n: usize, bins: u16) -> Vec<u8> {
        let mut rng = XorShift64Star::new(seed);
        (0..n).map(|_| rng.next_below(bins as u64) as u8).collect()
    }

    #[test]
    fn fused_matches_two_phase() {
        // The fused override must stay bit-identical to the two-phase
        // composition it replaces (tables first, SU after), across a
        // batch of mixed arities.
        let x = random_cols(1, 500, 8);
        let y = random_cols(2, 500, 4);
        let z = random_cols(7, 500, 3);
        let pairs = [
            ColumnPair {
                x: &x,
                bins_x: 8,
                y: &y,
                bins_y: 4,
            },
            ColumnPair {
                x: &z,
                bins_x: 3,
                y: &x,
                bins_y: 8,
            },
            ColumnPair {
                x: &y,
                bins_x: 4,
                y: &y,
                bins_y: 4,
            },
        ];
        let e = NativeEngine;
        let fused = e.su_from_column_pairs(&pairs);
        let tables = e.ctables(&pairs, 0..500);
        let two = e.su_from_tables(&tables.iter().collect::<Vec<_>>());
        assert_eq!(fused, two);
    }

    #[test]
    fn row_ranges_partition_the_work() {
        let x = random_cols(3, 1000, 4);
        let y = random_cols(4, 1000, 4);
        let pair = ColumnPair {
            x: &x,
            bins_x: 4,
            y: &y,
            bins_y: 4,
        };
        let e = NativeEngine;
        let whole = e.ctables(&[pair], 0..1000).remove(0);
        let mut a = e.ctables(&[pair], 0..300).remove(0);
        let b = e.ctables(&[pair], 300..1000).remove(0);
        a.merge(&b).unwrap();
        assert_eq!(whole, a);
    }

    #[test]
    fn matches_direct_su() {
        let x = random_cols(5, 400, 6);
        let y = random_cols(6, 400, 6);
        let e = NativeEngine;
        let got = e.su_from_column_pairs(&[ColumnPair {
            x: &x,
            bins_x: 6,
            y: &y,
            bins_y: 6,
        }])[0];
        let want = crate::correlation::su::symmetrical_uncertainty(&x, 6, &y, 6);
        assert_eq!(got, want);
    }

    #[test]
    fn empty_pairs() {
        let e = NativeEngine;
        assert!(e.su_from_column_pairs(&[]).is_empty());
        assert!(e.su_from_tables(&[]).is_empty());
    }
}
