//! `sparklet` — an in-process mini-Spark substrate.
//!
//! The paper's algorithms are expressed against the Spark primitives of
//! §4: RDDs with `mapPartitions` / `reduceByKey` / `collect`, driver-side
//! coordination, read-only broadcast, and shuffle. This module rebuilds
//! exactly that programming model in-process so DiCFS can be written the
//! way the paper writes it (see `dicfs::hp`, `dicfs::vp`).
//!
//! Two clocks:
//! * **Real execution** — every stage actually runs on a thread pool and
//!   produces real results (the selected features are never simulated).
//! * **Simulated cluster time** — every task is wall-clock timed; per-stage
//!   metrics (task times, shuffle bytes, broadcast bytes) feed
//!   [`simtime`], which schedules the measured tasks onto an
//!   `nodes × cores` virtual cluster (LPT) plus a network cost model.
//!   This is how Fig. 3/4/5's multi-node scaling is reproduced on a
//!   single-core host (DESIGN.md §2 — the substitution for the CESGA
//!   cluster).
//!
//! Fault tolerance: like Spark, failed tasks are retried ([`pool`];
//! `TaskOptions::max_retries`), which the failure-injection tests use.

pub mod config;
pub mod metrics;
pub mod pool;
pub mod rdd;
pub mod simtime;

pub use config::{ClusterConfig, NetworkModel};
pub use metrics::{JobMetrics, StageKind, StageMetrics};
pub use rdd::{Broadcast, Rdd, SparkletContext};
pub use simtime::simulate_job_time;
