//! L1 kernel throughput: the PJRT-executed Pallas artifacts (ctable, su,
//! fused) vs the native engine, in pairs/second and cells/second.
//!
//! This is the §Perf microbenchmark for the numeric hot path — see
//! EXPERIMENTS.md §Perf. The native engine is the practical roofline for
//! a CPU host (dense u64 scatter-count); the PJRT numbers measure the
//! one-hot-matmul formulation executed through XLA (compiled from the
//! interpret=True Pallas lowering — *structure*, not TPU performance).
//!
//! Output: table + `bench_out/kernel_throughput.csv`.

use std::time::Instant;

use dicfs::harness::report;
use dicfs::runtime::{ColumnPair, NativeEngine, SuEngine};
use dicfs::util::XorShift64Star;

fn bench_engine(engine: &dyn SuEngine, pairs: &[ColumnPair<'_>], reps: usize) -> (f64, f64) {
    // warmup (PJRT compiles lazily on first call)
    let _ = engine.su_from_column_pairs(&pairs[..1.min(pairs.len())]);
    let t0 = Instant::now();
    for _ in 0..reps {
        let su = engine.su_from_column_pairs(pairs);
        assert_eq!(su.len(), pairs.len());
    }
    let secs = t0.elapsed().as_secs_f64() / reps as f64;
    let n = pairs[0].x.len();
    let pairs_per_s = pairs.len() as f64 / secs;
    let cells_per_s = (pairs.len() * n) as f64 / secs;
    (pairs_per_s, cells_per_s)
}

fn main() {
    println!("== L1 kernel throughput: native vs PJRT (Pallas artifacts) ==\n");
    let mut rng = XorShift64Star::new(2024);
    let configs = [(32usize, 8192usize, 32u64), (32, 2048, 8), (8, 1024, 16)];

    let mut csv = Vec::new();
    let mut table_rows = Vec::new();
    for &(p, n, bins) in &configs {
        let xs: Vec<Vec<u8>> = (0..p)
            .map(|_| (0..n).map(|_| rng.next_below(bins) as u8).collect())
            .collect();
        let ys: Vec<Vec<u8>> = (0..p)
            .map(|_| (0..n).map(|_| rng.next_below(bins) as u8).collect())
            .collect();
        let pairs: Vec<ColumnPair> = xs
            .iter()
            .zip(&ys)
            .map(|(x, y)| ColumnPair {
                x,
                bins_x: bins as u16,
                y,
                bins_y: bins as u16,
            })
            .collect();

        let mut engines: Vec<(&str, Box<dyn SuEngine>)> =
            vec![("native", Box::new(NativeEngine))];
        #[cfg(feature = "pjrt")]
        {
            match dicfs::runtime::pjrt::PjrtEngine::from_default_dir() {
                Ok(e) => engines.push(("pjrt", Box::new(e))),
                Err(e) => eprintln!("skipping pjrt engine: {e}"),
            }
        }

        for (name, engine) in &engines {
            let (pps, cps) = bench_engine(engine.as_ref(), &pairs, 5);
            table_rows.push(vec![
                format!("P={p} N={n} B={bins}"),
                name.to_string(),
                format!("{pps:.0}"),
                format!("{:.1}", cps / 1e6),
            ]);
            csv.push(vec![
                p.to_string(),
                n.to_string(),
                bins.to_string(),
                name.to_string(),
                format!("{pps:.1}"),
                format!("{cps:.1}"),
            ]);
        }
    }

    let path = report::write_csv(
        "kernel_throughput.csv",
        &["pairs", "rows", "bins", "engine", "pairs_per_s", "cells_per_s"],
        &csv,
    );
    println!(
        "{}",
        dicfs::util::chart::table(
            &["shape", "engine", "pairs/s", "Mcells/s"],
            &table_rows
        )
    );
    println!("  data: {}", path.display());
}
