//! Best-first search over feature subsets — the paper's Algorithm 1.
//!
//! Key fidelity points:
//! * the queue is a *bounded* priority queue (capacity 5, the paper's
//!   `Queue.setCapacity(5)`),
//! * the stop criterion is five *consecutive* fails to improve on the
//!   best merit seen,
//! * correlations are fetched **on demand, batched per expansion** — the
//!   paper's §5 observation that makes the distributed versions one Spark
//!   job per search step. Every correlation flows through a
//!   [`CorrelationCache`], whose statistics feed the on-demand ablation.
//! * the ordering is fully deterministic (merit desc, then lexicographic
//!   feature list), so sequential/hp/vp runs traverse identical states.

use std::collections::{HashMap, HashSet};

use crate::cfs::locally_predictive::add_locally_predictive;
use crate::cfs::merit::merit_from_sums;
use crate::cfs::subset::SearchState;
use crate::cfs::Correlator;
use crate::core::{pair_key, FeatureId, SelectionResult, CLASS_ID};
use crate::correlation::sampled::SuInterval;
use crate::correlation::{CorrelationCache, MeasureCache};

/// A search-restart seed: feature subsets worth re-evaluating first —
/// the winning subset of a previous run, followed by its final priority
/// queue ([`BestFirstSearch::run_traced`] returns one).
///
/// Warm restarts are the incremental service's post-append accelerator
/// (DESIGN.md §12): after new instances arrive, the correlations shift
/// slightly, and re-seeding the search from where the last run ended
/// typically converges in a fraction of the expansions. The seed is
/// *advisory* — subsets are re-evaluated under the **current**
/// correlations before use, invalid feature ids are dropped, and an
/// empty seed degrades to an ordinary cold start. The warm result's
/// merit can only match or exceed the re-evaluated seed's, but its
/// trajectory (and thus, in principle, its subset) may differ from a
/// cold search's; exactness-critical paths use the cold search.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct WarmStart {
    /// Candidate subsets, best first. Order matters only as a tie-break
    /// hint; each subset is re-scored before seeding the queue.
    pub subsets: Vec<Vec<FeatureId>>,
}

impl WarmStart {
    /// True when the seed carries no subsets (cold start).
    pub fn is_empty(&self) -> bool {
        self.subsets.is_empty()
    }
}

/// Whether the search may use sampled SU **upper bounds** to skip exact
/// evaluation of provably-losing expansion candidates (DESIGN.md §16).
///
/// The selection is bit-identical either way — pruning only changes how
/// much exact correlation work is performed. `correlations_computed`
/// (and the new `sampled_cells`/`pruned_candidates` counters) are the
/// only observable differences.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PruneMode {
    /// Prune when the correlator offers sound bounds (the default).
    /// Planner-backed correlators additionally decline sketches that
    /// are not predicted to pay for themselves, which latches the
    /// search back to plain exact expansion.
    #[default]
    Auto,
    /// Never prune: every expansion candidate is evaluated exactly.
    Off,
}

impl PruneMode {
    /// Stable CLI label (`--prune auto|off`).
    pub fn label(&self) -> &'static str {
        match self {
            PruneMode::Auto => "auto",
            PruneMode::Off => "off",
        }
    }

    /// Parse a CLI label (the inverse of [`Self::label`]).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "auto" => Some(PruneMode::Auto),
            "off" => Some(PruneMode::Off),
            _ => None,
        }
    }
}

/// Search configuration (defaults = the paper's experimental setup).
#[derive(Debug, Clone, Copy)]
pub struct CfsConfig {
    /// Consecutive non-improving iterations before stopping (paper: 5).
    pub max_fails: usize,
    /// Priority-queue capacity (paper: 5).
    pub queue_capacity: usize,
    /// Run the locally-predictive post-step (paper experiments: true).
    pub locally_predictive: bool,
    /// Sketch-then-verify pruning mode (DESIGN.md §16).
    pub prune: PruneMode,
}

impl Default for CfsConfig {
    fn default() -> Self {
        Self {
            max_fails: 5,
            queue_capacity: 5,
            locally_predictive: true,
            prune: PruneMode::Auto,
        }
    }
}

/// Minimum candidate surplus over the queue capacity before the pruned
/// expansion engages: below this, the bookkeeping costs more than the
/// few exact evaluations it could save.
const PRUNE_MIN_EXCESS: usize = 3;

/// Run-local pruning state threaded through one search: the bounds memo
/// (sampled intervals never enter the exact cache, so without this a
/// pruned-at-root pair would be re-sketched at every later expansion),
/// the decline latch, and the counters surfaced via [`SelectionResult`].
struct PruneState {
    /// `config.prune == Auto`.
    enabled: bool,
    /// Set when the correlator declines a bounds request. Sketching is
    /// pointless after that (the backend has no sketch path, or its
    /// planner priced sketches out for this shape), so the rest of the
    /// search uses plain exact expansion.
    declined: bool,
    /// Sound SU intervals per canonical pair, valid for the whole run.
    memo: HashMap<(FeatureId, FeatureId), SuInterval>,
    /// Candidates skipped without an exact evaluation.
    pruned: usize,
    /// Total sketch cells scanned by bounds requests.
    sampled_cells: u64,
}

impl PruneState {
    fn new(mode: PruneMode) -> Self {
        Self {
            enabled: mode == PruneMode::Auto,
            declined: false,
            memo: HashMap::new(),
            pruned: 0,
            sampled_cells: 0,
        }
    }
}

/// The best-first search driver, generic over the correlation source.
pub struct BestFirstSearch {
    /// Configuration in effect.
    pub config: CfsConfig,
}

impl BestFirstSearch {
    /// Search with the given configuration.
    pub fn new(config: CfsConfig) -> Self {
        Self { config }
    }

    /// Run CFS over `m` features, pulling correlations from `correlator`.
    ///
    /// This is the single entry point used by SequentialCfs, DiCFS-hp,
    /// DiCFS-vp and RegCFS — they differ only in the `correlator`.
    pub fn run(&self, m: usize, correlator: &mut dyn Correlator) -> SelectionResult {
        let mut cache = CorrelationCache::new();
        self.run_with_cache(m, correlator, &mut cache)
    }

    /// [`Self::run`] with an external [`MeasureCache`] — an owned
    /// [`CorrelationCache`] (exposes hit/miss statistics to the ablation
    /// harness) or a per-query handle over a shared cache (the
    /// multi-query service, where concurrent searches reuse each other's
    /// correlations).
    pub fn run_with_cache(
        &self,
        m: usize,
        correlator: &mut dyn Correlator,
        cache: &mut dyn MeasureCache,
    ) -> SelectionResult {
        self.run_traced(m, correlator, cache, None).0
    }

    /// [`Self::run_with_cache`], optionally **warm-restarted**, returning
    /// the restart seed for the *next* run alongside the selection.
    ///
    /// With `warm = None` this is exactly the cold search (the plain
    /// entry points delegate here). With a seed, each subset is
    /// re-evaluated under the current correlations — one batched cache
    /// request for all of them, so the misses coalesce into a single
    /// distributed job — and the root is expanded eagerly (counted as
    /// the first iteration), so every singleton is evaluated and merged
    /// with the re-scored seeds before the bounded queue truncates: a
    /// degraded seed can never wall off the singleton frontier. The
    /// best resulting state is the incumbent, and the stop rule is
    /// unchanged: five consecutive failures to improve on it. Since the
    /// incumbent starts at the previous winner instead of merit 0, an
    /// unchanged (or mildly shifted) optimum is confirmed after
    /// `max_fails` expansions instead of being rebuilt feature by
    /// feature.
    #[must_use = "discarding the result also discards the warm-restart seed"]
    pub fn run_traced(
        &self,
        m: usize,
        correlator: &mut dyn Correlator,
        cache: &mut dyn MeasureCache,
        warm: Option<&WarmStart>,
    ) -> (SelectionResult, WarmStart) {
        let mut visited: HashSet<Vec<FeatureId>> = HashSet::new();
        visited.insert(vec![]);
        let mut fails = 0usize;
        let mut iterations = 0usize;
        let mut prune = PruneState::new(self.config.prune);
        let seeds = warm
            .map(|w| seed_states(m, w, correlator, cache))
            .unwrap_or_default();
        let (mut queue, mut best) = if seeds.is_empty() {
            (vec![SearchState::empty()], SearchState::empty())
        } else {
            let mut queue = seeds;
            for s in &queue {
                visited.insert(s.features.clone());
            }
            // Expand the cold root eagerly (this is the warm run's first
            // iteration): every singleton joins the queue alongside the
            // re-scored seeds *before* the capacity bound truncates, so
            // a degraded seed can never wall off the singleton frontier
            // the cold search would have started from.
            let root = SearchState::empty();
            iterations += 1;
            let candidates: Vec<FeatureId> = (0..m).collect();
            let singletons = expand_batch_pruned(
                &root,
                &candidates,
                correlator,
                cache,
                &mut visited,
                &queue,
                self.config.queue_capacity.max(1),
                &mut prune,
            );
            queue.extend(singletons);
            queue.sort_by(|a, b| a.cmp_priority(b));
            queue.truncate(self.config.queue_capacity.max(1));
            let best = queue[0].clone();
            (queue, best)
        };

        while fails < self.config.max_fails {
            // Dequeue the head (Algorithm 1 line 7); empty queue → done.
            if queue.is_empty() {
                break;
            }
            let head = queue.remove(0);
            iterations += 1;

            // Expand (line 8): all single-feature additions, evaluated in
            // one batched correlation request.
            let candidates: Vec<FeatureId> =
                (0..m).filter(|&f| !head.contains(f)).collect();
            let new_states = expand_batch_pruned(
                &head,
                &candidates,
                correlator,
                cache,
                &mut visited,
                &queue,
                self.config.queue_capacity,
                &mut prune,
            );

            // Enqueue (line 9) into the bounded priority queue.
            for s in new_states {
                let pos = queue
                    .binary_search_by(|q| q.cmp_priority(&s))
                    .unwrap_or_else(|p| p);
                queue.insert(pos, s);
            }
            queue.truncate(self.config.queue_capacity);

            if queue.is_empty() {
                break; // line 10-11: expansion exhausted the space
            }

            // Lines 13-19: compare the new queue head against the best.
            let local_best = &queue[0];
            if local_best.merit > best.merit + 1e-12 {
                best = local_best.clone();
                fails = 0;
            } else {
                fails += 1;
            }
        }

        let mut selected = best.features.clone();
        let mut locally_added = vec![];
        if self.config.locally_predictive && !selected.is_empty() {
            locally_added = add_locally_predictive(m, &mut selected, correlator, cache);
        }

        // Restart seed for the next run: the winner first, then whatever
        // the bounded queue still held when the search stopped.
        let mut warm_out = WarmStart::default();
        let mut seen: HashSet<Vec<FeatureId>> = HashSet::new();
        for features in std::iter::once(&best.features).chain(queue.iter().map(|s| &s.features)) {
            if !features.is_empty() && seen.insert(features.clone()) {
                warm_out.subsets.push(features.clone());
            }
        }

        (
            SelectionResult {
                selected,
                merit: best.merit,
                iterations,
                correlations_computed: cache.stats().computed,
                pruned_candidates: prune.pruned,
                sampled_cells: prune.sampled_cells,
                locally_predictive_added: locally_added,
            },
            warm_out,
        )
    }
}

/// Re-evaluate a warm seed's subsets under the current correlations:
/// sanitize (drop out-of-range ids, dedup, sort), fetch every needed
/// correlation in **one** batched cache request (misses coalesce into a
/// single distributed job), rebuild the [`SearchState`] sums, and return
/// the states sorted by search priority (best first).
fn seed_states(
    m: usize,
    warm: &WarmStart,
    correlator: &mut dyn Correlator,
    cache: &mut dyn MeasureCache,
) -> Vec<SearchState> {
    let mut subsets: Vec<Vec<FeatureId>> = Vec::new();
    let mut seen: HashSet<Vec<FeatureId>> = HashSet::new();
    for s in &warm.subsets {
        let mut v: Vec<FeatureId> = s.iter().copied().filter(|&f| f < m).collect();
        v.sort_unstable();
        v.dedup();
        if !v.is_empty() && seen.insert(v.clone()) {
            subsets.push(v);
        }
    }
    if subsets.is_empty() {
        return vec![];
    }

    let mut pairs: Vec<(FeatureId, FeatureId)> = Vec::new();
    for s in &subsets {
        for (i, &f) in s.iter().enumerate() {
            pairs.push((f, CLASS_ID));
            for &g in &s[i + 1..] {
                pairs.push((f, g));
            }
        }
    }
    let values = cache.batch(&pairs, &mut |missing| correlator.compute(missing));

    let mut states = Vec::with_capacity(subsets.len());
    let mut k = 0usize;
    for s in subsets {
        let mut sum_rcf = 0.0;
        let mut sum_rff = 0.0;
        for i in 0..s.len() {
            sum_rcf += values[k];
            k += 1;
            for _ in i + 1..s.len() {
                sum_rff += values[k];
                k += 1;
            }
        }
        let merit = merit_from_sums(s.len(), sum_rcf, sum_rff);
        states.push(SearchState {
            features: s,
            sum_rcf,
            sum_rff,
            merit,
        });
    }
    states.sort_by(|a, b| a.cmp_priority(b));
    states
}

/// Evaluate all expansions of `head` by `candidates`, requesting the
/// missing correlations in a single batch (the paper's `nc` pairs).
fn expand_batch(
    head: &SearchState,
    candidates: &[FeatureId],
    correlator: &mut dyn Correlator,
    cache: &mut dyn MeasureCache,
    visited: &mut HashSet<Vec<FeatureId>>,
) -> Vec<SearchState> {
    // Pair list: per candidate, (candidate, class) then (candidate, member)
    // for each current member.
    let mut pairs: Vec<(FeatureId, FeatureId)> = Vec::new();
    for &c in candidates {
        pairs.push((c, CLASS_ID));
        for &g in &head.features {
            pairs.push((c, g));
        }
    }
    let values = cache.batch(&pairs, &mut |missing| correlator.compute(missing));

    let stride = 1 + head.features.len();
    let mut out = Vec::with_capacity(candidates.len());
    for (i, &c) in candidates.iter().enumerate() {
        let base = i * stride;
        let rcf = values[base];
        let rffs = &values[base + 1..base + stride];
        let state = head.expanded(c, rcf, rffs);
        if visited.insert(state.features.clone()) {
            out.push(state);
        }
    }
    out
}

/// [`expand_batch`] with sketch-then-verify pruning (DESIGN.md §16).
///
/// Exactness argument (mirroring §12's delta-merge argument): children
/// influence the search *only* through the bounded queue, which the
/// caller truncates once per expansion to the top `capacity` states of
/// (post-pop queue ∪ children) under the total order `cmp_priority`.
/// The threshold computed here is the `capacity`-th best merit among a
/// **subset** of that union — the post-pop queue plus the children
/// already evaluated exactly — so the union's `capacity`-th best can
/// only be higher. A candidate is skipped only when its *optimistic*
/// merit is strictly below the threshold. The optimistic merit mirrors
/// [`SearchState::expanded`]'s accumulation step for step (one add for
/// rcf, an in-order sum for rff, the same [`merit_from_sums`] finish)
/// with element-wise dominating operands: rcf replaced by a sound upper
/// bound (cached exact value, sampled interval high end, or the trivial
/// 1.0) and each uncached rff replaced by 0 (SU is nonnegative). IEEE
/// add, sqrt and divide are monotone and the denominator is ≥ 1 for
/// `k ≥ 1`, so `upper ≥ exact merit` holds *in floating point*, not
/// just in ℝ — a pruned child's exact state would have been truncated
/// away by at least `capacity` strictly better states. Pruned children
/// are marked visited exactly as the exact run would have marked them,
/// so the visited set, queue trajectory and final selection stay
/// bit-identical; only `correlations_computed` (and the new counters)
/// differ.
#[allow(clippy::too_many_arguments)]
fn expand_batch_pruned(
    head: &SearchState,
    candidates: &[FeatureId],
    correlator: &mut dyn Correlator,
    cache: &mut dyn MeasureCache,
    visited: &mut HashSet<Vec<FeatureId>>,
    queue_rest: &[SearchState],
    capacity: usize,
    prune: &mut PruneState,
) -> Vec<SearchState> {
    if !prune.enabled
        || prune.declined
        || capacity == 0
        || candidates.len() < capacity + PRUNE_MIN_EXCESS
    {
        return expand_batch(head, candidates, correlator, cache, visited);
    }

    // Split candidates: "free" ones have every needed pair cached (their
    // exact evaluation computes nothing new); the rest are prune targets.
    struct Pending {
        c: FeatureId,
        rcf: Option<f64>,
        /// In-order sum of the cached rff values; uncached members
        /// contribute 0 (adding 0.0 is exact, so this equals the sum
        /// `SearchState::expanded` would form with those values zeroed).
        rff_lo_sum: f64,
    }
    let mut free: Vec<FeatureId> = Vec::new();
    let mut pending: Vec<Pending> = Vec::new();
    for &c in candidates {
        let rcf = cache.probe(c, CLASS_ID);
        let mut all_cached = rcf.is_some();
        let mut rff_lo_sum = 0.0;
        for &g in &head.features {
            match cache.probe(c, g) {
                Some(v) => rff_lo_sum += v,
                None => all_cached = false,
            }
        }
        if all_cached {
            free.push(c);
        } else {
            pending.push(Pending { c, rcf, rff_lo_sum });
        }
    }
    if pending.is_empty() {
        // Everything is cached: the exact expansion is already free.
        return expand_batch(head, candidates, correlator, cache, visited);
    }

    // Sampled bounds for pending candidates whose class pair is not
    // cached, memoized for the whole run (intervals never enter the
    // exact cache, so without the memo each later expansion would
    // re-sketch the same pairs).
    let need: Vec<(FeatureId, FeatureId)> = pending
        .iter()
        .filter(|p| p.rcf.is_none())
        .map(|p| pair_key(p.c, CLASS_ID))
        .filter(|k| !prune.memo.contains_key(k))
        .collect();
    if !need.is_empty() {
        match correlator.compute_bounds(&need) {
            Some(b) if b.intervals.len() == need.len() => {
                prune.sampled_cells += b.sampled_cells;
                for (k, iv) in need.iter().zip(b.intervals.iter()) {
                    prune.memo.insert(*k, *iv);
                }
            }
            _ => {
                // No sketch path (or the planner priced it out): latch
                // and revert to plain exact expansion for the rest of
                // the run.
                prune.declined = true;
                return expand_batch(head, candidates, correlator, cache, visited);
            }
        }
    }

    // Optimistic merit per pending candidate (see the doc comment for
    // why this dominates the exact child merit in floating point).
    let k1 = head.features.len() + 1;
    let uppers: Vec<f64> = pending
        .iter()
        .map(|p| {
            let rcf_hi = match p.rcf {
                Some(v) => v,
                None => prune
                    .memo
                    .get(&pair_key(p.c, CLASS_ID))
                    .map(|iv| iv.hi)
                    .unwrap_or(1.0),
            };
            merit_from_sums(k1, head.sum_rcf + rcf_hi, head.sum_rff + p.rff_lo_sum)
        })
        .collect();

    // Wave 1: evaluate the free set (cache hits only); if the threshold
    // pool is still short of `capacity` — a cold root, mostly — add the
    // most promising pending candidates so the queue cut is known.
    // (On a warm re-query everything evaluated by the previous run is
    // free, so this wave adds nothing and no new pairs are computed.)
    let mut children = expand_batch(head, &free, correlator, cache, visited);
    let mut evaluated: HashSet<FeatureId> = free.into_iter().collect();
    if queue_rest.len() + children.len() < capacity {
        let mut order: Vec<usize> = (0..pending.len()).collect();
        order.sort_by(|&i, &j| {
            uppers[j]
                .partial_cmp(&uppers[i])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(pending[i].c.cmp(&pending[j].c))
        });
        let wave1: Vec<FeatureId> = order
            .iter()
            .take(capacity)
            .map(|&i| pending[i].c)
            .collect();
        children.extend(expand_batch(head, &wave1, correlator, cache, visited));
        evaluated.extend(wave1);
    }

    // Queue-cut threshold: the capacity-th best merit among the post-pop
    // queue and the exactly-evaluated children — a lower bound on the
    // capacity-th best of the full union the exact run truncates to
    // (adding the remaining children can only raise it).
    let mut pool: Vec<f64> = queue_rest
        .iter()
        .chain(children.iter())
        .map(|s| s.merit)
        .collect();
    if pool.len() < capacity {
        // Too few known states to bound the queue cut: nothing can be
        // pruned soundly, evaluate the remainder exactly.
        let rest: Vec<FeatureId> = pending
            .iter()
            .map(|p| p.c)
            .filter(|c| !evaluated.contains(c))
            .collect();
        children.extend(expand_batch(head, &rest, correlator, cache, visited));
        return children;
    }
    pool.sort_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
    let threshold = pool[capacity - 1];

    // Wave 2: skip candidates whose optimistic merit is *strictly* below
    // the threshold (ties must be evaluated — only a strict deficit
    // proves the exact child loses the cut); evaluate the rest exactly.
    let mut survivors: Vec<FeatureId> = Vec::new();
    for (p, &upper) in pending.iter().zip(uppers.iter()) {
        if evaluated.contains(&p.c) {
            continue;
        }
        if upper < threshold {
            // The exact run would evaluate this child and immediately
            // truncate it away; mark it visited exactly as that run
            // would have, and skip the exact work.
            let mut feats = head.features.clone();
            let pos = feats.partition_point(|&g| g < p.c);
            feats.insert(pos, p.c);
            visited.insert(feats);
            prune.pruned += 1;
        } else {
            survivors.push(p.c);
        }
    }
    children.extend(expand_batch(head, &survivors, correlator, cache, visited));
    children
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    /// Correlator over a fixed SU matrix, counting batch calls.
    struct TableCorrelator {
        su: HashMap<(FeatureId, FeatureId), f64>,
        calls: usize,
    }

    impl TableCorrelator {
        fn new(m: usize, rcf: &[f64], rff: &[(usize, usize, f64)]) -> Self {
            let mut su = HashMap::new();
            for (f, &v) in rcf.iter().enumerate() {
                su.insert(crate::core::pair_key(f, CLASS_ID), v);
            }
            for f in 0..m {
                for g in 0..m {
                    if f < g {
                        su.insert((f, g), 0.0);
                    }
                }
            }
            for &(a, b, v) in rff {
                su.insert(crate::core::pair_key(a, b), v);
            }
            Self { su, calls: 0 }
        }
    }

    impl Correlator for TableCorrelator {
        fn compute(&mut self, pairs: &[(FeatureId, FeatureId)]) -> Vec<f64> {
            self.calls += 1;
            pairs.iter().map(|&(a, b)| self.su[&crate::core::pair_key(a, b)]).collect()
        }
    }

    /// [`TableCorrelator`] that also answers bounds requests with a
    /// ±`width` interval around the exact value (always sound here).
    struct BoundsCorrelator {
        inner: TableCorrelator,
        width: f64,
        bounds_calls: usize,
    }

    impl Correlator for BoundsCorrelator {
        fn compute(&mut self, pairs: &[(FeatureId, FeatureId)]) -> Vec<f64> {
            self.inner.compute(pairs)
        }

        fn compute_bounds(
            &mut self,
            pairs: &[(FeatureId, FeatureId)],
        ) -> Option<crate::correlation::SuBounds> {
            self.bounds_calls += 1;
            let intervals = pairs
                .iter()
                .map(|&(a, b)| {
                    let v = self.inner.su[&crate::core::pair_key(a, b)];
                    SuInterval {
                        lo: (v - self.width).max(0.0),
                        hi: v + self.width,
                    }
                })
                .collect();
            Some(crate::correlation::SuBounds {
                intervals,
                sampled_cells: pairs.len() as u64 * 10,
            })
        }
    }

    fn cfg_no_lp() -> CfsConfig {
        CfsConfig {
            locally_predictive: false,
            ..CfsConfig::default()
        }
    }

    #[test]
    fn selects_relevant_uncorrelated_features() {
        // f0, f1 strongly class-correlated & independent; f2 weak; f3 a
        // near-copy of f0 (redundant).
        let mut corr = TableCorrelator::new(
            4,
            &[0.8, 0.7, 0.1, 0.75],
            &[(0, 3, 0.95), (0, 1, 0.05), (1, 3, 0.05)],
        );
        let r = BestFirstSearch::new(cfg_no_lp()).run(4, &mut corr);
        assert_eq!(r.selected, vec![0, 1], "redundant f3 and weak f2 rejected");
        assert!(r.merit > 0.9);
    }

    #[test]
    fn single_strong_feature() {
        let mut corr = TableCorrelator::new(3, &[0.9, 0.0, 0.0], &[]);
        let r = BestFirstSearch::new(cfg_no_lp()).run(3, &mut corr);
        assert_eq!(r.selected, vec![0]);
        assert!((r.merit - 0.9).abs() < 1e-9);
    }

    #[test]
    fn all_zero_correlations_select_nothing() {
        let mut corr = TableCorrelator::new(5, &[0.0; 5], &[]);
        let r = BestFirstSearch::new(cfg_no_lp()).run(5, &mut corr);
        assert!(r.selected.is_empty());
        assert_eq!(r.merit, 0.0);
    }

    #[test]
    fn one_batch_per_iteration() {
        let mut corr = TableCorrelator::new(6, &[0.5, 0.4, 0.3, 0.2, 0.1, 0.0], &[]);
        let r = BestFirstSearch::new(cfg_no_lp()).run(6, &mut corr);
        // on-demand batching: number of correlator calls == iterations
        // that had at least one cache miss ≤ iterations.
        assert!(corr.calls <= r.iterations);
        assert!(r.correlations_computed <= 6 * 7 / 2 + 6);
    }

    #[test]
    fn respects_max_fails_stop() {
        // Only f0 matters: after selecting it, expansions can't improve,
        // so the search must stop after max_fails iterations.
        let mut corr = TableCorrelator::new(10, &[0.9, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0], &[]);
        let r = BestFirstSearch::new(cfg_no_lp()).run(10, &mut corr);
        assert_eq!(r.selected, vec![0]);
        assert!(r.iterations <= 1 + 5 + 1, "iterations: {}", r.iterations);
    }

    #[test]
    fn deterministic_across_runs() {
        let build = || {
            TableCorrelator::new(
                8,
                &[0.6, 0.6, 0.5, 0.5, 0.3, 0.3, 0.0, 0.0],
                &[(0, 1, 0.9), (2, 3, 0.8)],
            )
        };
        let a = BestFirstSearch::new(cfg_no_lp()).run(8, &mut build());
        let b = BestFirstSearch::new(cfg_no_lp()).run(8, &mut build());
        assert_eq!(a, b);
    }

    #[test]
    fn zero_features_empty_result() {
        let mut corr = TableCorrelator::new(0, &[], &[]);
        let r = BestFirstSearch::new(cfg_no_lp()).run(0, &mut corr);
        assert!(r.selected.is_empty());
    }

    #[test]
    fn traced_with_no_seed_is_the_cold_search() {
        let build = || {
            TableCorrelator::new(
                6,
                &[0.6, 0.5, 0.4, 0.3, 0.2, 0.1],
                &[(0, 1, 0.7), (2, 3, 0.6)],
            )
        };
        let search = BestFirstSearch::new(cfg_no_lp());
        let cold = search.run(6, &mut build());
        let mut cache = CorrelationCache::new();
        let (traced, warm_out) = search.run_traced(6, &mut build(), &mut cache, None);
        assert_eq!(traced, cold, "run_traced(None) must be the cold search");
        // The trace names the winner first.
        assert_eq!(warm_out.subsets.first(), Some(&cold.selected));
        assert!(!warm_out.is_empty());
    }

    #[test]
    fn warm_restart_confirms_unchanged_optimum_in_fewer_iterations() {
        let build = || {
            TableCorrelator::new(
                4,
                &[0.8, 0.7, 0.1, 0.75],
                &[(0, 3, 0.95), (0, 1, 0.05), (1, 3, 0.05)],
            )
        };
        let search = BestFirstSearch::new(cfg_no_lp());
        let mut c1 = CorrelationCache::new();
        let (cold, seed) = search.run_traced(4, &mut build(), &mut c1, None);
        assert_eq!(cold.selected, vec![0, 1]);

        // Correlations unchanged: the warm run re-confirms the winner
        // after max_fails expansions instead of rebuilding the path.
        let mut c2 = CorrelationCache::new();
        let (warm, _) = search.run_traced(4, &mut build(), &mut c2, Some(&seed));
        assert_eq!(warm.selected, cold.selected);
        assert!((warm.merit - cold.merit).abs() < 1e-12);
        assert!(
            warm.iterations < cold.iterations,
            "warm {} vs cold {} iterations",
            warm.iterations,
            cold.iterations
        );
    }

    #[test]
    fn warm_seed_is_sanitized_not_trusted() {
        let mut corr = TableCorrelator::new(3, &[0.9, 0.1, 0.0], &[]);
        // Out-of-range ids, duplicates, an empty subset, a duplicate
        // subset: all must be dropped or canonicalized, never panic.
        let seed = WarmStart {
            subsets: vec![vec![7, 9], vec![], vec![1, 1, 0], vec![0, 1], vec![99]],
        };
        let mut cache = CorrelationCache::new();
        let (r, _) = BestFirstSearch::new(cfg_no_lp()).run_traced(3, &mut corr, &mut cache, Some(&seed));
        assert_eq!(r.selected, vec![0], "search still finds the optimum");

        // A fully-invalid seed degrades to the cold search.
        let garbage = WarmStart {
            subsets: vec![vec![42], vec![]],
        };
        let mut corr2 = TableCorrelator::new(3, &[0.9, 0.1, 0.0], &[]);
        let mut cache2 = CorrelationCache::new();
        let (r2, _) =
            BestFirstSearch::new(cfg_no_lp()).run_traced(3, &mut corr2, &mut cache2, Some(&garbage));
        let cold = BestFirstSearch::new(cfg_no_lp()).run(3, &mut TableCorrelator::new(3, &[0.9, 0.1, 0.0], &[]));
        assert_eq!(r2, cold);
    }

    /// Regression: a capacity-filling seed of mediocre multi-feature
    /// subsets must not wall off the singleton frontier. Before the
    /// eager root expansion, the seeds evicted the root from the bounded
    /// queue (while poisoning `visited`), so the search could never
    /// evaluate any singleton and returned a strictly worse subset.
    #[test]
    fn warm_seed_cannot_wall_off_the_singleton_frontier() {
        let mut corr = TableCorrelator::new(3, &[0.9, 0.05, 0.04], &[]);
        let seed = WarmStart {
            subsets: vec![
                vec![1, 2],
                vec![0, 1],
                vec![0, 2],
                vec![0, 1, 2],
                vec![1],
                vec![2],
            ],
        };
        let mut cache = CorrelationCache::new();
        let (r, _) =
            BestFirstSearch::new(cfg_no_lp()).run_traced(3, &mut corr, &mut cache, Some(&seed));
        // The optimum is the singleton [0], reachable only from the root.
        assert_eq!(r.selected, vec![0]);
        assert!((r.merit - 0.9).abs() < 1e-9);
    }

    #[test]
    fn warm_seed_correlations_fetch_in_one_batch() {
        let mut corr = TableCorrelator::new(5, &[0.5, 0.4, 0.3, 0.2, 0.1], &[]);
        let seed = WarmStart {
            subsets: vec![vec![0, 1], vec![0, 2], vec![3]],
        };
        let mut cache = CorrelationCache::new();
        // Drive the seeding step directly: all three subsets must be
        // re-evaluated through exactly one batched correlator call.
        let states = seed_states(5, &seed, &mut corr, &mut cache);
        assert_eq!(corr.calls, 1, "seeding must batch every subset's pairs");
        assert_eq!(states.len(), 3);
        // Sorted best-first, with sums matching a direct evaluation:
        // merit([0,1]) = (0.5 + 0.4) / sqrt(2) with zero rff.
        assert_eq!(states[0].features, vec![0, 1]);
        assert!((states[0].merit - 0.9 / 2f64.sqrt()).abs() < 1e-12);
        assert_eq!(states[2].features, vec![3]);
        assert!((states[2].merit - 0.2).abs() < 1e-12);
    }

    /// A 12-feature table with a clear relevance gradient: enough
    /// candidates over the capacity-5 queue for the pruned expansion to
    /// engage, enough hopeless features for it to actually prune.
    fn gradient_table() -> TableCorrelator {
        let rcf: Vec<f64> = (0..12).map(|i| (0.85 - 0.08 * i as f64).max(0.0)).collect();
        TableCorrelator::new(12, &rcf, &[(0, 1, 0.9), (2, 3, 0.55)])
    }

    #[test]
    fn pruned_search_is_bit_identical_and_cheaper() {
        let exact_cfg = CfsConfig {
            prune: PruneMode::Off,
            ..cfg_no_lp()
        };
        let exact = BestFirstSearch::new(exact_cfg).run(
            12,
            &mut BoundsCorrelator {
                inner: gradient_table(),
                width: 0.02,
                bounds_calls: 0,
            },
        );
        let mut pruned_corr = BoundsCorrelator {
            inner: gradient_table(),
            width: 0.02,
            bounds_calls: 0,
        };
        let pruned = BestFirstSearch::new(cfg_no_lp()).run(12, &mut pruned_corr);

        // Everything the search decides on is bit-identical...
        assert_eq!(pruned.selected, exact.selected);
        assert_eq!(pruned.merit.to_bits(), exact.merit.to_bits());
        assert_eq!(pruned.iterations, exact.iterations);
        assert_eq!(
            pruned.locally_predictive_added,
            exact.locally_predictive_added
        );
        // ...but the pruned run did strictly less exact work.
        assert!(pruned.pruned_candidates > 0, "nothing was pruned");
        assert!(pruned.sampled_cells > 0, "no sketch was requested");
        assert!(
            pruned.correlations_computed < exact.correlations_computed,
            "pruned computed {} vs exact {}",
            pruned.correlations_computed,
            exact.correlations_computed
        );
        assert!(pruned_corr.bounds_calls > 0);
        assert_eq!(exact.pruned_candidates, 0);
        assert_eq!(exact.sampled_cells, 0);
    }

    #[test]
    fn prune_off_never_requests_bounds() {
        let mut corr = BoundsCorrelator {
            inner: gradient_table(),
            width: 0.02,
            bounds_calls: 0,
        };
        let cfg = CfsConfig {
            prune: PruneMode::Off,
            ..cfg_no_lp()
        };
        let _ = BestFirstSearch::new(cfg).run(12, &mut corr);
        assert_eq!(corr.bounds_calls, 0);
    }

    #[test]
    fn declined_bounds_latch_back_to_the_exact_search() {
        // TableCorrelator has no sketch path: the first bounds request
        // declines, the search latches to exact expansion, and the
        // result (including call counts) matches PruneMode::Off exactly.
        let mut auto_corr = gradient_table();
        let auto = BestFirstSearch::new(cfg_no_lp()).run(12, &mut auto_corr);
        let mut off_corr = gradient_table();
        let off_cfg = CfsConfig {
            prune: PruneMode::Off,
            ..cfg_no_lp()
        };
        let off = BestFirstSearch::new(off_cfg).run(12, &mut off_corr);
        assert_eq!(auto, off);
        assert_eq!(auto_corr.calls, off_corr.calls);
        assert_eq!(auto.pruned_candidates, 0);
        assert_eq!(auto.sampled_cells, 0);
    }

    #[test]
    fn trivial_bound_caps_cannot_break_exactness() {
        // Very wide intervals (width 1.0 → hi caps at ≥ 1) must never
        // prune wrongly; they just prune nothing.
        let mut corr = BoundsCorrelator {
            inner: gradient_table(),
            width: 1.0,
            bounds_calls: 0,
        };
        let pruned = BestFirstSearch::new(cfg_no_lp()).run(12, &mut corr);
        let exact = BestFirstSearch::new(CfsConfig {
            prune: PruneMode::Off,
            ..cfg_no_lp()
        })
        .run(12, &mut gradient_table());
        assert_eq!(pruned.selected, exact.selected);
        assert_eq!(pruned.merit.to_bits(), exact.merit.to_bits());
    }

    #[test]
    fn cache_stats_reported() {
        let mut corr = TableCorrelator::new(4, &[0.5, 0.4, 0.3, 0.2], &[]);
        let search = BestFirstSearch::new(cfg_no_lp());
        let mut cache = CorrelationCache::new();
        let r = search.run_with_cache(4, &mut corr, &mut cache);
        assert_eq!(r.correlations_computed, cache.stats().computed);
        assert!(cache.stats().requested >= cache.stats().computed);
    }
}
