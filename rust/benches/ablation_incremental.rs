//! Ablation for incremental DiCFS (DESIGN.md §12): append-and-requery
//! vs cold re-registration.
//!
//! Workload, per tenant: a stream of instances split into a base batch
//! and a delta batch.
//! * **incremental** — register the base, query (fills the versioned SU
//!   cache), `append` the delta, query again: cached pairs are
//!   *upgraded* by merging only the delta rows' counts; only genuinely
//!   new pairs are computed over the full rows. A third, warm-restarted
//!   query measures the search-side saving.
//! * **cold re-registration** — a fresh service registers the merged
//!   data from scratch and queries: every pair is computed over all
//!   rows (what the pre-incremental service had to do after any
//!   append).
//!
//! Asserted acceptance bars (the ISSUE's):
//! * **Equal results**: the incremental post-append query selects the
//!   same subset, with bit-identical merit, as the cold re-registration
//!   query (and both match a from-scratch sequential run).
//! * **Strictly fewer SU cells**: the incremental path's post-append
//!   scan work (`delta_cells + full_cells` of its version-1 jobs) stays
//!   strictly below the cold path's (`full_cells` of its jobs), and its
//!   from-scratch pair computations are strictly fewer too.
//! * **Warm restart**: the warm-restarted query expands no more search
//!   states than the cold post-append query.
//!
//! Output: table + `bench_out/ablation_incremental.csv` +
//! `bench_out/BENCH_incremental.json` (the machine-readable perf
//! trajectory for this bench).

use std::sync::Arc;

use dicfs::cfs::best_first::CfsConfig;
use dicfs::cfs::SequentialCfs;
use dicfs::data::synth::{by_name, SynthConfig};
use dicfs::discretize::discretize_dataset;
use dicfs::harness::{bench_scale, report};
use dicfs::serve::{AlgoSpec, DicfsService, QuerySpec, ServeScheme, ServiceConfig};
use dicfs::sparklet::ClusterConfig;
use dicfs::util::chart::table;

struct Row {
    tenant: &'static str,
    scheme: ServeScheme,
    base_rows: usize,
    delta_rows: usize,
    cold_pairs: usize,
    cold_cells: u64,
    incr_fresh_pairs: usize,
    incr_upgraded_pairs: usize,
    incr_cells: u64,
    cold_iters: usize,
    warm_iters: usize,
}

fn service() -> DicfsService {
    DicfsService::new(ServiceConfig {
        cluster: ClusterConfig::with_nodes(4),
        max_inflight_jobs: 2,
        ..ServiceConfig::default()
    })
}

fn main() {
    let scale = bench_scale();
    println!("== Ablation: incremental append-and-requery vs cold re-registration (scale {scale}) ==\n");

    let rows = |base: usize| ((base as f64 * scale) as usize).max(400);
    let tenants: [(&'static str, &'static str, ServeScheme, usize, u64); 2] = [
        ("higgs-hp", "higgs", ServeScheme::Horizontal, rows(3_000), 17),
        ("epsilon-auto", "epsilon", ServeScheme::Auto, rows(1_600), 29),
    ];

    let spec_cfs = CfsConfig::default();
    let mut out_rows: Vec<Row> = Vec::new();

    for (tenant, family, scheme, base_rows, seed) in tenants {
        let delta_rows = (base_rows / 6).max(50);
        let total = base_rows + delta_rows;
        let raw = by_name(
            family,
            &SynthConfig {
                rows: total,
                seed,
                features: Some(14),
            },
        );
        let full = Arc::new(discretize_dataset(&raw).unwrap());
        let scratch = SequentialCfs::new(spec_cfs).select_discrete(&full);

        // COLD RE-REGISTRATION: merged data from scratch.
        let cold_svc = service();
        let cold_id = cold_svc.register_discrete(tenant, Arc::clone(&full), scheme, None);
        let cold = cold_svc.query(&QuerySpec {
            dataset: cold_id,
            cfs: spec_cfs,
            algo: AlgoSpec::Cfs,
        });
        assert_eq!(cold.result.selected, scratch.selected, "{tenant}: cold run broke");
        let cold_jobs = cold_svc.job_log();
        let cold_pairs: usize = cold_jobs.iter().map(|j| j.computed_pairs).sum();
        let cold_cells: u64 = cold_jobs
            .iter()
            .map(|j| j.full_cells + j.delta_cells)
            .sum();

        // INCREMENTAL: base → query → append → query (+ warm restart).
        let incr_svc = service();
        let incr_id = incr_svc.register_discrete(
            tenant,
            Arc::new(full.slice_rows(0..base_rows)),
            scheme,
            None,
        );
        let spec = QuerySpec {
            dataset: incr_id,
            cfs: spec_cfs,
            algo: AlgoSpec::Cfs,
        };
        let pre = incr_svc.query(&spec);
        incr_svc
            .append_discrete(incr_id, &full.slice_rows(base_rows..total))
            .unwrap();
        let post = incr_svc.query(&spec);
        let warm = incr_svc.query_warm(&spec, &pre.warm);

        // Equal results: incremental ≡ cold re-registration ≡ scratch.
        assert_eq!(
            post.result.selected, cold.result.selected,
            "{tenant}: append-and-requery diverged from cold re-registration"
        );
        assert_eq!(
            post.result.merit.to_bits(),
            cold.result.merit.to_bits(),
            "{tenant}: merit not bit-identical"
        );

        // Post-append work = the version-1 jobs only.
        let incr_jobs: Vec<_> = incr_svc
            .job_log()
            .into_iter()
            .filter(|j| j.version == 1)
            .collect();
        let incr_fresh_pairs: usize = incr_jobs
            .iter()
            .map(|j| j.computed_pairs - j.upgraded_pairs)
            .sum();
        let incr_upgraded_pairs: usize = incr_jobs.iter().map(|j| j.upgraded_pairs).sum();
        let incr_cells: u64 = incr_jobs
            .iter()
            .map(|j| j.full_cells + j.delta_cells)
            .sum();

        assert!(
            incr_upgraded_pairs > 0,
            "{tenant}: no cached pair was delta-upgraded"
        );
        assert!(
            incr_cells < cold_cells,
            "{tenant}: incremental scanned {incr_cells} cells, cold only {cold_cells}"
        );
        assert!(
            incr_fresh_pairs < cold_pairs,
            "{tenant}: incremental computed {incr_fresh_pairs} pairs from scratch vs cold {cold_pairs}"
        );
        assert!(
            warm.result.iterations <= post.result.iterations,
            "{tenant}: warm restart expanded more states ({} vs {})",
            warm.result.iterations,
            post.result.iterations
        );

        out_rows.push(Row {
            tenant,
            scheme,
            base_rows,
            delta_rows,
            cold_pairs,
            cold_cells,
            incr_fresh_pairs,
            incr_upgraded_pairs,
            incr_cells,
            cold_iters: post.result.iterations,
            warm_iters: warm.result.iterations,
        });
    }

    let trows: Vec<Vec<String>> = out_rows
        .iter()
        .map(|r| {
            vec![
                r.tenant.to_string(),
                r.scheme.label().to_string(),
                format!("{}+{}", r.base_rows, r.delta_rows),
                r.cold_pairs.to_string(),
                r.cold_cells.to_string(),
                format!("{}f/{}u", r.incr_fresh_pairs, r.incr_upgraded_pairs),
                r.incr_cells.to_string(),
                format!("{:.1}x", r.cold_cells as f64 / r.incr_cells.max(1) as f64),
                format!("{}/{}", r.warm_iters, r.cold_iters),
            ]
        })
        .collect();
    println!(
        "{}",
        table(
            &[
                "tenant",
                "scheme",
                "rows (base+delta)",
                "cold pairs",
                "cold cells",
                "incr pairs (fresh/upgraded)",
                "incr cells",
                "cell saving",
                "warm/cold iters",
            ],
            &trows
        )
    );

    let csv: Vec<Vec<String>> = out_rows
        .iter()
        .map(|r| {
            vec![
                r.tenant.to_string(),
                r.scheme.label().to_string(),
                r.base_rows.to_string(),
                r.delta_rows.to_string(),
                r.cold_pairs.to_string(),
                r.cold_cells.to_string(),
                r.incr_fresh_pairs.to_string(),
                r.incr_upgraded_pairs.to_string(),
                r.incr_cells.to_string(),
                r.cold_iters.to_string(),
                r.warm_iters.to_string(),
            ]
        })
        .collect();
    let path = report::write_csv(
        "ablation_incremental.csv",
        &[
            "tenant",
            "scheme",
            "base_rows",
            "delta_rows",
            "cold_pairs",
            "cold_cells",
            "incr_fresh_pairs",
            "incr_upgraded_pairs",
            "incr_cells",
            "cold_iters",
            "warm_iters",
        ],
        &csv,
    );

    // Machine-readable perf trajectory (one JSON per bench run).
    let tenants_json: Vec<String> = out_rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "    {{\"tenant\": \"{}\", \"scheme\": \"{}\", ",
                    "\"base_rows\": {}, \"delta_rows\": {}, ",
                    "\"cold_pairs\": {}, \"cold_cells\": {}, ",
                    "\"incr_fresh_pairs\": {}, \"incr_upgraded_pairs\": {}, ",
                    "\"incr_cells\": {}, \"cold_iters\": {}, \"warm_iters\": {}}}"
                ),
                r.tenant,
                r.scheme.label(),
                r.base_rows,
                r.delta_rows,
                r.cold_pairs,
                r.cold_cells,
                r.incr_fresh_pairs,
                r.incr_upgraded_pairs,
                r.incr_cells,
                r.cold_iters,
                r.warm_iters
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"incremental\",\n  \"tenants\": [\n{}\n  ]\n}}\n",
        tenants_json.join(",\n")
    );
    let json_path = report::out_dir().join("BENCH_incremental.json");
    std::fs::write(&json_path, json).expect("write BENCH_incremental.json");

    println!(
        "ablation_incremental: PASS (equal results, strictly fewer SU cells than cold re-registration)"
    );
    println!("  data: {}", path.display());
    println!("  perf trajectory: {}\n", json_path.display());
}
