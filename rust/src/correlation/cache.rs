//! On-demand correlation caches — the paper's §5 key optimization.
//!
//! "trying to calculate all correlations in any dataset with a high number
//! of features and instances is prohibitive; [...] a very low percentage of
//! correlations is actually used during the search and on-demand
//! correlation calculation is around 100 times faster".
//!
//! The best-first driver asks a cache for a *batch* of pairs at each
//! expansion; only the misses are forwarded (still batched) to the
//! underlying correlator — which is what makes a single distributed job per
//! search step possible. Two implementations of the [`SuCache`] funnel:
//!
//! * [`CorrelationCache`] — the single-search cache every standalone
//!   `select` run owns. Hit/miss counters feed the `ablation_ondemand`
//!   bench that reproduces the claim.
//! * [`SharedSuCache`] — the thread-safe, interior-mutability variant the
//!   multi-query service (`crate::serve`) keeps alive per registered
//!   dataset, so concurrent searches hit each other's correlations.
//!   Statistics are **per query handle** ([`SuCacheHandle`]): `requested`
//!   / `hits` / `computed` describe one search, never the union of every
//!   search that ever touched the shared map (see
//!   [`CacheStats::fraction_of_full_matrix`]). The number of distinct
//!   pairs in the shared map is reported separately by
//!   [`SharedSuCache::len`].

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, RwLock};

use crate::core::{pair_key, FeatureId};

/// Cache statistics for the on-demand ablation and per-query reporting.
///
/// Under cache *sharing* these counters are scoped to one query handle:
/// `requested` counts the pairs one search asked for, `hits` the pairs it
/// was served without computation (whether warmed by itself or by another
/// query), `computed` the misses it forwarded to a correlator. Summing
/// handles therefore never double-counts a query's traffic into another
/// query's statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Pairs requested by the search (including repeats).
    pub requested: usize,
    /// Pairs served from the cache.
    pub hits: usize,
    /// Distinct pairs this search forwarded to its correlator.
    pub computed: usize,
}

impl CacheStats {
    /// Fraction of the full `C(m+1, 2)` correlation matrix that this
    /// search computed for a dataset with `m` features (+ class).
    ///
    /// The statistics are per search (per query handle when the cache is
    /// shared), so the fraction stays meaningful under the multi-query
    /// service: a warm query that hit everything reports `0.0` here even
    /// though the shared map already holds many pairs.
    pub fn fraction_of_full_matrix(&self, m: usize) -> f64 {
        let full = (m + 1) * m / 2;
        if full == 0 {
            0.0
        } else {
            self.computed as f64 / full as f64
        }
    }

    /// Hit rate over all requests (`0.0` when nothing was requested).
    pub fn hit_rate(&self) -> f64 {
        if self.requested == 0 {
            0.0
        } else {
            self.hits as f64 / self.requested as f64
        }
    }
}

/// The single funnel through which every correlation in the system flows.
///
/// Sequential CFS, DiCFS-hp, DiCFS-vp and the multi-query service differ
/// only in the `compute` callback they plug in and in which implementor
/// backs the funnel: [`CorrelationCache`] (one search, owned) or
/// [`SuCacheHandle`] (one query over a [`SharedSuCache`]).
pub trait SuCache {
    /// Serve `pairs`, calling `compute` at most once with the
    /// (deduplicated, insertion-ordered, canonically-keyed) list of
    /// misses. `compute` must return one value per missing pair, in
    /// order.
    fn batch(
        &mut self,
        pairs: &[(FeatureId, FeatureId)],
        compute: &mut dyn FnMut(&[(FeatureId, FeatureId)]) -> Vec<f64>,
    ) -> Vec<f64>;

    /// Statistics of the requests served through this cache (per query
    /// handle when the backing store is shared).
    fn stats(&self) -> CacheStats;
}

/// Symmetric, on-demand correlation cache owned by a single search.
#[derive(Debug, Default)]
pub struct CorrelationCache {
    map: HashMap<(FeatureId, FeatureId), f64>,
    stats: CacheStats,
}

impl CorrelationCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Look up a single pair (symmetric).
    pub fn get(&self, a: FeatureId, b: FeatureId) -> Option<f64> {
        self.map.get(&pair_key(a, b)).copied()
    }

    /// Insert a computed value (symmetric key).
    pub fn insert(&mut self, a: FeatureId, b: FeatureId, value: f64) {
        self.map.insert(pair_key(a, b), value);
    }

    /// Serve `pairs`, calling `compute` once with the (deduplicated,
    /// insertion-ordered) list of misses. `compute` must return one value
    /// per missing pair, in order. See [`SuCache::batch`] for the
    /// dyn-friendly form the search drivers use.
    pub fn get_or_compute_batch(
        &mut self,
        pairs: &[(FeatureId, FeatureId)],
        compute: impl FnOnce(&[(FeatureId, FeatureId)]) -> Vec<f64>,
    ) -> Vec<f64> {
        self.stats.requested += pairs.len();

        let mut missing: Vec<(FeatureId, FeatureId)> = Vec::new();
        let mut seen: HashSet<(FeatureId, FeatureId)> = HashSet::new();
        for &(a, b) in pairs {
            let k = pair_key(a, b);
            if !self.map.contains_key(&k) && seen.insert(k) {
                missing.push(k);
            }
        }
        self.stats.hits += pairs.len() - missing.len();

        if !missing.is_empty() {
            let values = compute(&missing);
            assert_eq!(
                values.len(),
                missing.len(),
                "correlator returned {} values for {} pairs",
                values.len(),
                missing.len()
            );
            self.stats.computed += missing.len();
            for (k, v) in missing.iter().zip(values) {
                self.map.insert(*k, v);
            }
        }

        pairs
            .iter()
            .map(|&(a, b)| self.map[&pair_key(a, b)])
            .collect()
    }

    /// Cache statistics snapshot.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Number of distinct cached pairs.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

impl SuCache for CorrelationCache {
    fn batch(
        &mut self,
        pairs: &[(FeatureId, FeatureId)],
        compute: &mut dyn FnMut(&[(FeatureId, FeatureId)]) -> Vec<f64>,
    ) -> Vec<f64> {
        self.get_or_compute_batch(pairs, |missing| compute(missing))
    }

    fn stats(&self) -> CacheStats {
        self.stats
    }
}

/// Thread-safe SU cache shared by every query on one registered dataset.
///
/// Values are held behind an `RwLock`; queries interact through
/// [`SuCacheHandle`]s, which carry the per-query statistics. Inserting the
/// same pair twice is harmless by construction: SU is a pure function of
/// the dataset and every engine in this repo computes it bit-identically
/// (DESIGN.md §5), so concurrent writers can only agree.
#[derive(Debug, Clone, Default)]
pub struct SharedSuCache {
    map: Arc<RwLock<HashMap<(FeatureId, FeatureId), f64>>>,
}

impl SharedSuCache {
    /// Empty shared cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// A fresh per-query handle over this shared map (statistics start at
    /// zero for each handle).
    pub fn handle(&self) -> SuCacheHandle {
        SuCacheHandle {
            shared: self.clone(),
            stats: CacheStats::default(),
        }
    }

    /// Look up a single pair (symmetric).
    pub fn get(&self, a: FeatureId, b: FeatureId) -> Option<f64> {
        self.map.read().unwrap().get(&pair_key(a, b)).copied()
    }

    /// Look up a batch under a single read guard (one lock acquisition
    /// however long the batch). Returns `None` if any pair is missing.
    pub fn get_batch(&self, pairs: &[(FeatureId, FeatureId)]) -> Option<Vec<f64>> {
        let map = self.map.read().unwrap();
        pairs
            .iter()
            .map(|&(a, b)| map.get(&pair_key(a, b)).copied())
            .collect()
    }

    /// Insert a batch of computed values under canonical keys. `pairs`
    /// and `values` must be the same length.
    ///
    /// Skips the write lock entirely when every pair is already present —
    /// the common case for query handles whose misses were published by a
    /// coalesced scheduler job moments earlier — so publishing never
    /// blocks other queries' read-guard hot path without need.
    pub fn insert_batch(&self, pairs: &[(FeatureId, FeatureId)], values: &[f64]) {
        assert_eq!(pairs.len(), values.len(), "pair/value length mismatch");
        {
            let map = self.map.read().unwrap();
            if pairs
                .iter()
                .all(|&(a, b)| map.contains_key(&pair_key(a, b)))
            {
                return;
            }
        }
        let mut map = self.map.write().unwrap();
        for (&(a, b), &v) in pairs.iter().zip(values) {
            map.insert(pair_key(a, b), v);
        }
    }

    /// Of the given pairs, return those not yet cached (canonical keys,
    /// input order) — one read-guard acquisition for the whole scan.
    pub fn missing_of(&self, pairs: &[(FeatureId, FeatureId)]) -> Vec<(FeatureId, FeatureId)> {
        let map = self.map.read().unwrap();
        pairs
            .iter()
            .map(|&(a, b)| pair_key(a, b))
            .filter(|k| !map.contains_key(k))
            .collect()
    }

    /// Number of distinct pairs ever computed into this cache — the
    /// service-level "distinct SU pairs" metric (per-query `computed`
    /// lives on the handles).
    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    /// True when no pair has been computed yet.
    pub fn is_empty(&self) -> bool {
        self.map.read().unwrap().is_empty()
    }
}

/// One query's view of a [`SharedSuCache`]: shares the value map with
/// every other handle, owns its own [`CacheStats`].
#[derive(Debug)]
pub struct SuCacheHandle {
    shared: SharedSuCache,
    stats: CacheStats,
}

impl SuCacheHandle {
    /// The shared cache this handle draws from.
    pub fn shared(&self) -> &SharedSuCache {
        &self.shared
    }
}

impl SuCache for SuCacheHandle {
    fn batch(
        &mut self,
        pairs: &[(FeatureId, FeatureId)],
        compute: &mut dyn FnMut(&[(FeatureId, FeatureId)]) -> Vec<f64>,
    ) -> Vec<f64> {
        self.stats.requested += pairs.len();

        // One pass under one read guard: collect found values and the
        // deduplicated miss list together, so a fully-warm batch (the
        // service's hot path) costs a single lock acquisition and one
        // hash lookup per pair. The lock is released before `compute`,
        // which may block on a coalesced distributed job.
        let mut found: Vec<Option<f64>> = Vec::with_capacity(pairs.len());
        let mut missing: Vec<(FeatureId, FeatureId)> = Vec::new();
        {
            let map = self.shared.map.read().unwrap();
            let mut seen: HashSet<(FeatureId, FeatureId)> = HashSet::new();
            for &(a, b) in pairs {
                let k = pair_key(a, b);
                let v = map.get(&k).copied();
                if v.is_none() && seen.insert(k) {
                    missing.push(k);
                }
                found.push(v);
            }
        }
        self.stats.hits += pairs.len() - missing.len();

        if missing.is_empty() {
            return found.into_iter().map(|v| v.expect("all hits")).collect();
        }

        let values = compute(&missing);
        assert_eq!(
            values.len(),
            missing.len(),
            "correlator returned {} values for {} pairs",
            values.len(),
            missing.len()
        );
        self.stats.computed += missing.len();
        // Another query may have inserted some of these pairs while we
        // computed; the values are identical (pure function of the
        // dataset), so overwriting is benign.
        self.shared.insert_batch(&missing, &values);

        // Patch the holes from the just-computed values — no second trip
        // through the shared map.
        let patch: HashMap<(FeatureId, FeatureId), f64> =
            missing.into_iter().zip(values).collect();
        pairs
            .iter()
            .zip(found)
            .map(|(&(a, b), v)| v.unwrap_or_else(|| patch[&pair_key(a, b)]))
            .collect()
    }

    fn stats(&self) -> CacheStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn computes_once_then_hits() {
        let mut c = CorrelationCache::new();
        let mut calls = 0;
        let v = c.get_or_compute_batch(&[(0, 1), (1, 2)], |miss| {
            calls += 1;
            miss.iter().map(|&(a, b)| (a + b) as f64).collect()
        });
        assert_eq!(v, vec![1.0, 3.0]);
        assert_eq!(calls, 1);

        // Second request: all hits, compute not called.
        let v2 = c.get_or_compute_batch(&[(1, 0), (2, 1)], |_| panic!("no misses expected"));
        assert_eq!(v2, vec![1.0, 3.0]);
        let s = c.stats();
        assert_eq!(s.requested, 4);
        assert_eq!(s.hits, 2);
        assert_eq!(s.computed, 2);
    }

    #[test]
    fn symmetric_keys_share_entries() {
        let mut c = CorrelationCache::new();
        c.insert(5, 3, 0.7);
        assert_eq!(c.get(3, 5), Some(0.7));
        assert_eq!(c.get(5, 3), Some(0.7));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn duplicate_misses_computed_once() {
        let mut c = CorrelationCache::new();
        let v = c.get_or_compute_batch(&[(0, 1), (1, 0), (0, 1)], |miss| {
            assert_eq!(miss.len(), 1);
            vec![0.5]
        });
        assert_eq!(v, vec![0.5, 0.5, 0.5]);
        assert_eq!(c.stats().computed, 1);
    }

    #[test]
    fn class_id_pairs_work() {
        use crate::core::CLASS_ID;
        let mut c = CorrelationCache::new();
        let v = c.get_or_compute_batch(&[(3, CLASS_ID)], |m| {
            assert_eq!(m[0], (3, CLASS_ID)); // canonical: feature < CLASS_ID
            vec![0.9]
        });
        assert_eq!(v, vec![0.9]);
        assert_eq!(c.get(CLASS_ID, 3), Some(0.9));
    }

    #[test]
    fn fraction_of_full_matrix() {
        let s = CacheStats {
            requested: 100,
            hits: 40,
            computed: 60,
        };
        // m = 10 features: full matrix = 55 pairs (incl. class pairs)
        assert!((s.fraction_of_full_matrix(10) - 60.0 / 55.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "correlator returned")]
    fn mismatched_correlator_output_panics() {
        let mut c = CorrelationCache::new();
        c.get_or_compute_batch(&[(0, 1)], |_| vec![]);
    }

    #[test]
    fn trait_batch_matches_inherent_behavior() {
        let mut c = CorrelationCache::new();
        let v = SuCache::batch(&mut c, &[(0, 1), (2, 3)], &mut |miss| {
            miss.iter().map(|&(a, b)| (a * 10 + b) as f64).collect()
        });
        assert_eq!(v, vec![1.0, 23.0]);
        assert_eq!(SuCache::stats(&c).computed, 2);
    }

    #[test]
    fn shared_cache_serves_second_handle_from_first_handle_work() {
        let shared = SharedSuCache::new();
        let mut a = shared.handle();
        let mut b = shared.handle();

        let va = a.batch(&[(0, 1), (0, 2)], &mut |miss| {
            miss.iter().map(|&(x, y)| (x + y) as f64).collect()
        });
        assert_eq!(va, vec![1.0, 2.0]);

        // b requests an overlapping set: the overlap is a hit with no
        // computation, only the new pair is forwarded.
        let vb = b.batch(&[(0, 1), (1, 2)], &mut |miss| {
            assert_eq!(miss, &[(1, 2)]);
            vec![3.0]
        });
        assert_eq!(vb, vec![1.0, 3.0]);

        assert_eq!(a.stats().computed, 2);
        assert_eq!(b.stats().hits, 1);
        assert_eq!(b.stats().computed, 1);
        assert_eq!(shared.len(), 3);
    }

    /// Regression: per-query statistics must not double-count traffic
    /// from other queries on the same shared cache —
    /// `fraction_of_full_matrix` stays a per-search number.
    #[test]
    fn shared_stats_are_per_handle_not_global() {
        let m = 4; // full matrix: C(5, 2) = 10 pairs
        let shared = SharedSuCache::new();

        let mut warmup = shared.handle();
        let all: Vec<(FeatureId, FeatureId)> = (0..m)
            .flat_map(|a| (a + 1..=m).map(move |b| (a, b)))
            .collect();
        assert_eq!(all.len(), 10);
        let _ = warmup.batch(&all, &mut |miss| vec![0.5; miss.len()]);
        assert!((warmup.stats().fraction_of_full_matrix(m) - 1.0).abs() < 1e-12);

        // A warm query that only hits must report 0 computed — before the
        // per-handle split, the single embedded CacheStats would have
        // reported the warm query's `requested` on top of the warmup's
        // and its fraction as if it had computed the matrix itself.
        let mut warm = shared.handle();
        let _ = warm.batch(&all[..4], &mut |_| panic!("warm query must not compute"));
        let s = warm.stats();
        assert_eq!(s.requested, 4);
        assert_eq!(s.hits, 4);
        assert_eq!(s.computed, 0);
        assert_eq!(s.fraction_of_full_matrix(m), 0.0);

        // The warmup handle's view is unchanged by the warm query.
        assert_eq!(warmup.stats().requested, 10);
        assert_eq!(shared.len(), 10);
    }

    #[test]
    fn missing_of_scans_under_one_guard() {
        let shared = SharedSuCache::new();
        shared.insert_batch(&[(0, 1), (2, 3)], &[0.1, 0.2]);
        assert_eq!(shared.missing_of(&[(1, 0), (4, 5), (2, 3)]), vec![(4, 5)]);
        assert!(shared.missing_of(&[(0, 1)]).is_empty());
        // insert_batch over already-present pairs is a read-only no-op.
        shared.insert_batch(&[(1, 0)], &[0.1]);
        assert_eq!(shared.len(), 2);
    }

    #[test]
    fn shared_cache_concurrent_handles_agree() {
        let shared = SharedSuCache::new();
        let pairs: Vec<(FeatureId, FeatureId)> =
            (0..16).flat_map(|a| (a + 1..16).map(move |b| (a, b))).collect();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let shared = shared.clone();
                let pairs = pairs.clone();
                s.spawn(move || {
                    let mut h = shared.handle();
                    let v = h.batch(&pairs, &mut |miss| {
                        miss.iter().map(|&(a, b)| (a * 100 + b) as f64).collect()
                    });
                    let want: Vec<f64> =
                        pairs.iter().map(|&(a, b)| (a * 100 + b) as f64).collect();
                    assert_eq!(v, want);
                });
            }
        });
        assert_eq!(shared.len(), pairs.len());
    }
}
