//! Regenerates paper Figure 5: speed-up (Eq. 5, relative to 2 nodes) vs
//! cluster size for DiCFS-hp and DiCFS-vp on all four families.
//!
//! Output: ASCII charts + `bench_out/fig5_speedup.csv`.

use dicfs::harness::{bench_scale, fig5};

fn main() {
    let scale = bench_scale();
    println!("== Figure 5: speed-up vs nodes (scale {scale}) ==\n");
    let curves = fig5::run(scale, &[2, 3, 4, 5, 6, 7, 8, 9, 10], 10);
    fig5::emit(&curves);
}
