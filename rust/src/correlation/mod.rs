//! Information-theoretic and statistical correlation measures.
//!
//! This is the numeric core of CFS (paper §3): contingency tables →
//! entropies → symmetrical uncertainty (Eq. 2–3), plus Pearson correlation
//! for the RegCFS comparison (Table 2). The math here mirrors
//! `python/compile/kernels/ref.py` exactly — the golden fixtures in
//! `artifacts/fixtures/` pin both sides together.

pub mod cache;
pub mod ctable;
pub mod entropy;
pub mod pearson;
pub mod sampled;
pub mod su;

pub use cache::{
    CacheStats, CorrelationCache, SharedSuCache, SuCache, SuCacheHandle, VersionedEntry,
    VersionedSuCache, VersionedSuHandle, ENTRY_OVERHEAD_BYTES, MAX_BOUND_ENTRIES,
    SCALAR_ENTRY_BYTES,
};
pub use ctable::ContingencyTable;
pub use sampled::{
    bounds_for_pairs, default_windows, sample_ranges, windows_len, Marginals, SuBounds,
    SuInterval,
};
pub use su::{su_from_table, symmetrical_uncertainty};
