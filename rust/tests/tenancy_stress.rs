//! Adversarial multi-tenant battery (DESIGN.md §15): a hot tenant
//! floods the service while cold tenants trickle queries, every tenant
//! runs under a different cache budget (including a pathological
//! zero-byte one) and DRR weight, and a sampler thread watches resident
//! cache bytes the whole time. The contracts under attack:
//!
//! * **Exactness** — every query's selection and merit are bit-identical
//!   to an isolated sequential run, no matter how much eviction and
//!   recomputation the budgets force.
//! * **Bounded memory** — each budgeted tenant's resident bytes stay
//!   under its budget at every sampled tick, and the post-hoc peak
//!   counter agrees.
//! * **Fairness** — no tenant starves: the DRR scheduler dispatches jobs
//!   for every tenant and records its weight.
//! * **Lifecycle** — over-ceiling registrations are rejected with a
//!   typed error, and retiring a tenant mid-flood frees its capacity
//!   for a newcomer.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use dicfs::cfs::best_first::CfsConfig;
use dicfs::cfs::SequentialCfs;
use dicfs::data::columnar::DiscreteDataset;
use dicfs::data::synth::{by_name, SynthConfig};
use dicfs::discretize::discretize_dataset;
use dicfs::serve::{
    worst_case_cache_bytes, AlgoSpec, CacheBudget, DicfsService, QuerySpec, RegisterOptions,
    ServeScheme, ServiceConfig,
};
use dicfs::sparklet::ClusterConfig;

fn discrete(family: &str, rows: usize, features: usize, seed: u64) -> Arc<DiscreteDataset> {
    let ds = by_name(
        family,
        &SynthConfig {
            rows,
            seed,
            features: Some(features),
        },
    );
    Arc::new(discretize_dataset(&ds).unwrap())
}

/// A config mix that forces distinct search trajectories (and therefore
/// distinct SU working sets) per query.
fn config_mix() -> Vec<CfsConfig> {
    vec![
        CfsConfig::default(),
        CfsConfig {
            max_fails: 3,
            ..CfsConfig::default()
        },
        CfsConfig {
            locally_predictive: false,
            ..CfsConfig::default()
        },
        CfsConfig {
            max_fails: 2,
            queue_capacity: 3,
            locally_predictive: false,
            ..CfsConfig::default()
        },
    ]
}

struct Tenant {
    name: &'static str,
    data: Arc<DiscreteDataset>,
    budget: CacheBudget,
    weight: f64,
}

/// One hot tenant hammering the service with 3x the cold tenants'
/// traffic, four budget regimes (5%, 25%, 25%, zero bytes), weights
/// spanning 8x. Everything the ISSUE's acceptance criteria demand from
/// the adversarial workload, asserted in one run.
#[test]
fn hot_tenant_flood_stays_exact_fair_and_bounded() {
    let hot_data = discrete("higgs", 700, 10, 3);
    let tenants = vec![
        Tenant {
            name: "hot",
            budget: CacheBudget::Bytes(worst_case_cache_bytes(&hot_data) / 20),
            data: hot_data,
            weight: 2.0,
        },
        Tenant {
            name: "cold-a",
            data: discrete("kddcup99", 500, 8, 4),
            budget: CacheBudget::Inherit, // resolves to the service default below
            weight: 1.0,
        },
        Tenant {
            name: "cold-b",
            data: discrete("higgs", 450, 9, 7),
            budget: CacheBudget::Bytes(0), // pathological: nothing may stay resident
            weight: 1.0,
        },
        Tenant {
            name: "cold-c",
            data: discrete("epsilon", 400, 10, 9),
            budget: CacheBudget::Unbounded,
            weight: 0.25,
        },
    ];

    // The service default budget (picked up by cold-a via Inherit).
    let cold_a_quarter = worst_case_cache_bytes(&tenants[1].data) / 4;
    let svc = DicfsService::with_engine_pool(
        ServiceConfig {
            cluster: ClusterConfig::with_nodes(3),
            max_inflight_jobs: 2,
            cache_budget_bytes: Some(cold_a_quarter),
            ..ServiceConfig::default()
        },
        vec![Arc::new(dicfs::runtime::NativeEngine)],
    );

    let ids: Vec<usize> = tenants
        .iter()
        .map(|t| {
            svc.try_register_discrete(
                t.name,
                Arc::clone(&t.data),
                ServeScheme::Horizontal,
                RegisterOptions {
                    partitions: None,
                    budget: t.budget,
                    weight: t.weight,
                },
            )
            .expect("registration under no ceiling cannot overload")
        })
        .collect();

    // Isolated ground truth per (tenant, config), computed before any
    // shared state exists.
    let configs = config_mix();
    let baselines: Vec<Vec<_>> = tenants
        .iter()
        .map(|t| {
            configs
                .iter()
                .map(|&cfs| SequentialCfs::new(cfs).select_discrete(&t.data))
                .collect()
        })
        .collect();

    // Sampler: poll resident bytes of every budgeted tenant while the
    // flood runs. A single over-budget tick is a failure.
    let stop = AtomicBool::new(false);
    let ticks = AtomicUsize::new(0);
    let violations = AtomicUsize::new(0);

    std::thread::scope(|s| {
        s.spawn(|| {
            while !stop.load(Ordering::Relaxed) {
                for r in svc.cache_reports() {
                    if let Some(budget) = r.budget_bytes {
                        if r.resident_bytes > budget {
                            violations.fetch_add(1, Ordering::Relaxed);
                            eprintln!(
                                "tick violation: {} resident {} > budget {}",
                                r.name, r.resident_bytes, budget
                            );
                        }
                    }
                }
                ticks.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(1));
            }
        });

        // Hot tenant: 3 full passes over the config mix (12 queries).
        // Cold tenants: one pass each (4 queries), concurrently.
        let mut handles = Vec::new();
        for (ti, _t) in tenants.iter().enumerate() {
            let rounds = if ti == 0 { 3 } else { 1 };
            let id = ids[ti];
            let configs = &configs;
            let svc = &svc;
            handles.push((
                ti,
                s.spawn(move || {
                    let mut reports = Vec::new();
                    for _ in 0..rounds {
                        for (ci, &cfs) in configs.iter().enumerate() {
                            let spec = QuerySpec {
                                dataset: id,
                                cfs,
                                algo: AlgoSpec::Cfs,
                            };
                            reports.push((ci, svc.query(&spec)));
                        }
                    }
                    reports
                }),
            ));
        }
        for (ti, h) in handles {
            for (ci, report) in h.join().expect("tenant thread panicked") {
                let want = &baselines[ti][ci];
                assert_eq!(
                    report.result.selected, want.selected,
                    "tenant {} config {} selection diverged under flood",
                    tenants[ti].name, ci
                );
                assert_eq!(
                    report.result.merit.to_bits(),
                    want.merit.to_bits(),
                    "tenant {} config {} merit not bit-identical",
                    tenants[ti].name,
                    ci
                );
            }
        }
        stop.store(true, Ordering::Relaxed);
    });

    assert!(ticks.load(Ordering::Relaxed) > 0, "sampler never ran");
    assert_eq!(
        violations.load(Ordering::Relaxed),
        0,
        "resident cache bytes exceeded a tenant budget mid-flood"
    );

    // Post-hoc accounting per tenant.
    let reports = svc.cache_reports();
    assert_eq!(reports.len(), tenants.len());
    for (t, r) in tenants.iter().zip(&reports) {
        assert_eq!(r.name, t.name);
        if let Some(budget) = r.budget_bytes {
            assert!(
                r.peak_resident_bytes <= budget,
                "{}: peak {} exceeds budget {}",
                t.name,
                r.peak_resident_bytes,
                budget
            );
        }
    }
    // The 5%-budget hot tenant and the zero-budget tenant must have
    // actually evicted; the zero-budget tenant ends empty.
    assert!(reports[0].evicted_pairs > 0, "5% budget never evicted");
    assert!(reports[2].evicted_pairs > 0, "zero budget never evicted");
    assert_eq!(reports[2].resident_bytes, 0, "zero-budget tenant kept bytes");
    assert_eq!(reports[2].peak_resident_bytes, 0);
    assert_eq!(reports[1].budget_bytes, Some(cold_a_quarter), "Inherit did not pick up the default");
    assert_eq!(reports[3].budget_bytes, None, "Unbounded tenant got a budget");

    // Recompute accounting: fresh SU computations cover what is resident
    // plus what was evicted (recomputes of evicted pairs are counted
    // again, so >= — but never less).
    let jobs = svc.job_log();
    for (i, r) in reports.iter().enumerate() {
        let computed: usize = jobs
            .iter()
            .filter(|j| j.dataset == ids[i])
            .map(|j| j.computed_pairs)
            .sum();
        assert!(
            computed >= r.distinct_pairs + r.evicted_pairs,
            "{}: computed {} < resident {} + evicted {}",
            r.name,
            computed,
            r.distinct_pairs,
            r.evicted_pairs
        );
    }

    // Fairness: every tenant was dispatched, with its weight on record,
    // and the stats cover the whole job log.
    let stats = svc.tenant_stats();
    assert_eq!(stats.len(), tenants.len());
    for (t, st) in tenants.iter().zip(&stats) {
        assert_eq!(st.dataset_name, t.name);
        assert!(
            (st.weight - t.weight).abs() < 1e-12,
            "{}: weight {} not recorded",
            t.name,
            st.weight
        );
        assert!(st.jobs > 0, "{}: starved (no jobs dispatched)", t.name);
        assert!(st.drr_cost_pairs > 0, "{}: no DRR cost charged", t.name);
    }
    assert_eq!(stats.iter().map(|s| s.jobs).sum::<usize>(), jobs.len());
    // The flooding tenant demanded 3x the work; DRR serves demand, it
    // does not invert it.
    assert!(
        stats[0].jobs >= stats[1].jobs.min(stats[2].jobs),
        "hot tenant dispatched less than a cold tenant"
    );
}

/// Service-wide ceiling: admission is typed, retiring mid-flood frees
/// capacity for a previously-rejected newcomer, and the survivor's
/// queries stay exact throughout.
#[test]
fn ceiling_rejects_then_retire_admits_under_flood() {
    let dd_a = discrete("higgs", 600, 9, 11);
    let dd_b = discrete("kddcup99", 500, 8, 12);
    let dd_c = discrete("higgs", 500, 9, 13);

    let demand = |d: &DiscreteDataset| d.footprint_bytes() + worst_case_cache_bytes(d);
    // One byte short of all three: c is rejected while b is live, and
    // admitted once b's (strictly larger) demand is freed.
    let ceiling = demand(&dd_a) + demand(&dd_b) + demand(&dd_c) - 1;
    let svc = DicfsService::new(ServiceConfig {
        cluster: ClusterConfig::with_nodes(2),
        max_inflight_jobs: 2,
        max_service_bytes: Some(ceiling),
        ..ServiceConfig::default()
    });

    let a = svc
        .try_register_discrete(
            "a",
            Arc::clone(&dd_a),
            ServeScheme::Horizontal,
            RegisterOptions::default(),
        )
        .unwrap();
    let b = svc
        .try_register_discrete(
            "b",
            Arc::clone(&dd_b),
            ServeScheme::Horizontal,
            RegisterOptions::default(),
        )
        .unwrap();

    // c cannot fit while a and b hold their worst-case demand.
    let err = svc
        .try_register_discrete(
            "c",
            Arc::clone(&dd_c),
            ServeScheme::Horizontal,
            RegisterOptions::default(),
        )
        .unwrap_err();
    assert!(
        matches!(err, dicfs::core::Error::Overloaded(_)),
        "expected typed Overloaded, got {err:?}"
    );

    let iso_a = SequentialCfs::default().select_discrete(&dd_a);
    let iso_b = SequentialCfs::default().select_discrete(&dd_b);

    std::thread::scope(|s| {
        // Tenant a floods in the background for the whole scene.
        let flood = s.spawn(|| {
            (0..6)
                .map(|_| {
                    svc.query(&QuerySpec {
                        dataset: a,
                        cfs: CfsConfig::default(),
                        algo: AlgoSpec::Cfs,
                    })
                })
                .collect::<Vec<_>>()
        });

        // Warm b, then retire it mid-flood; its capacity admits c.
        let rb = svc.query(&QuerySpec {
            dataset: b,
            cfs: CfsConfig::default(),
            algo: AlgoSpec::Cfs,
        });
        assert_eq!(rb.result.selected, iso_b.selected);

        let before = svc.total_demand_bytes();
        let (freed_pairs, freed_bytes) = svc.unregister(b).unwrap();
        assert!(freed_pairs > 0, "warmed tenant freed no cached pairs");
        assert!(freed_bytes > 0);
        assert!(svc.total_demand_bytes() < before, "retire freed no demand");

        let c = svc
            .try_register_discrete(
                "c",
                Arc::clone(&dd_c),
                ServeScheme::Horizontal,
                RegisterOptions::default(),
            )
            .expect("capacity freed by retire must admit c");
        let rc = svc.query(&QuerySpec {
            dataset: c,
            cfs: CfsConfig::default(),
            algo: AlgoSpec::Cfs,
        });
        let iso_c = SequentialCfs::default().select_discrete(&dd_c);
        assert_eq!(rc.result.selected, iso_c.selected);
        assert_eq!(rc.result.merit.to_bits(), iso_c.merit.to_bits());

        for r in flood.join().expect("flood thread panicked") {
            assert_eq!(
                r.result.selected, iso_a.selected,
                "survivor's selection changed while a neighbor was retired"
            );
            assert_eq!(r.result.merit.to_bits(), iso_a.merit.to_bits());
        }
    });

    // The retired id is dead; the name is reusable.
    assert!(svc.unregister(b).is_err(), "double retire must be typed");
    assert!(svc.cache_report(b).is_none());
}
